//! AllReduce topologies: deterministic pairwise-summation schedules.
//!
//! The paper's grid ran a *binary tree* AllReduce between mappers
//! (§4.1, the Terascale design of Agarwal et al. 2011); CoCoA-era
//! systems favour bandwidth-optimal *rings*; a *flat* master gather is
//! the baseline every Hadoop shuffle degenerates to. All three are
//! expressed here as an explicit [`ReducePlan`]: an ordered list of
//! `dst += src` accumulation steps over per-rank vectors (chunked for
//! the ring). Because the plan fixes the floating-point summation
//! order, a reduction is **bitwise reproducible** — independent of
//! thread scheduling, of the transport that carried the parts (in-proc
//! or TCP), and of the physical routing. Both the simulated cluster and
//! the TCP driver execute the *same* plan through [`reduce`], which is
//! what lets `net_smoke` demand exact agreement between transports.

use crate::linalg;

/// Logical reduction topology, selectable per run via
/// `[cluster] topology` in the config (see `coordinator/config.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Master gathers and adds every rank's vector in rank order:
    /// P−1 sequential vector transfers over the master link.
    Flat,
    /// Stride-doubling binary tree (the paper's §4.1 AllReduce; the
    /// default, and bitwise-identical to the seed implementation).
    Tree,
    /// Bandwidth-optimal ring: the vector is split into P chunks and
    /// chunk c is accumulated travelling around the ring starting at
    /// rank c (the reduce-scatter half of ring-allreduce).
    Ring,
    /// Rabenseifner-style recursive halving-doubling: reduce-scatter by
    /// recursive halving (each of log₂P exchange levels ships half the
    /// surviving range) followed by the mirrored recursive-doubling
    /// allgather. Moves the bandwidth-optimal 2·(P−1)/P·m elements per
    /// rank — like the ring — but in only 2·log₂P serialized exchange
    /// levels instead of 2·(P−1) ring steps. Non-power-of-two P is
    /// handled by the standard fold-in pre-step: the trailing P−q ranks
    /// (q the largest power of two ≤ P) first fold their whole vector
    /// into a low-rank survivor, and the mirrored broadcast folds the
    /// result back out.
    HalvingDoubling,
    /// The stride-doubling tree split into [`PIPELINE_CHUNKS`] pipeline
    /// chunks: every chunk runs the same tree step list, so successive
    /// chunks overlap on the wire (chunk c's level-k frame rides behind
    /// chunk c−1's level-k+1 frame on the same connection) — the
    /// footnote-8 "pipelined tree" the paper's cost model assumes.
    PipelinedTree,
}

/// Pipeline depth of [`Topology::PipelinedTree`]: the vector is split
/// into this many equal chunks (short vectors leave trailing chunks
/// empty, which compile to no ops at all).
pub const PIPELINE_CHUNKS: usize = 4;

impl Topology {
    pub fn from_name(name: &str) -> Option<Topology> {
        match name {
            "flat" => Some(Topology::Flat),
            "tree" => Some(Topology::Tree),
            "ring" => Some(Topology::Ring),
            "hd" | "halving_doubling" => Some(Topology::HalvingDoubling),
            "ptree" | "pipelined_tree" => Some(Topology::PipelinedTree),
            _ => None,
        }
    }

    /// The strict config/CLI entry point: normalizes the `-`/`_` alias
    /// convention used for method names, accepts the long and short
    /// spellings of every topology, and rejects anything else with an
    /// error that lists the valid set.
    pub fn parse(name: &str) -> Result<Topology, String> {
        let canon = name.trim().to_ascii_lowercase().replace('-', "_");
        Topology::from_name(&canon).ok_or_else(|| {
            format!(
                "unknown topology {name:?}: expected one of \
                 flat | tree | ring | hd (halving_doubling) | \
                 ptree (pipelined_tree) | auto"
            )
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::Flat => "flat",
            Topology::Tree => "tree",
            Topology::Ring => "ring",
            Topology::HalvingDoubling => "hd",
            Topology::PipelinedTree => "ptree",
        }
    }

    pub fn all() -> [Topology; 5] {
        [
            Topology::Flat,
            Topology::Tree,
            Topology::Ring,
            Topology::HalvingDoubling,
            Topology::PipelinedTree,
        ]
    }

    /// The deterministic reduction schedule for P ranks and m-vectors.
    pub fn plan(&self, p: usize, m: usize) -> ReducePlan {
        assert!(p > 0, "plan over zero ranks");
        let chunks = match self {
            Topology::Flat => {
                let steps = (1..p).map(|s| (0, s)).collect();
                vec![Chunk { lo: 0, hi: m, steps, root: 0 }]
            }
            Topology::Tree => {
                vec![Chunk { lo: 0, hi: m, steps: tree_steps(p), root: 0 }]
            }
            Topology::Ring => (0..p)
                .map(|c| {
                    let steps = (0..p.saturating_sub(1))
                        .map(|k| (((c + k + 1) % p), ((c + k) % p)))
                        .collect();
                    Chunk {
                        lo: c * m / p,
                        hi: (c + 1) * m / p,
                        steps,
                        root: (c + p - 1) % p,
                    }
                })
                .collect(),
            Topology::HalvingDoubling => {
                // q = largest power of two ≤ p; ranks q..p fold their
                // whole vector into survivors 0..p−q before the
                // power-of-two halving exchange, and the mirrored
                // broadcast folds the result back out to them.
                let q = if p.is_power_of_two() {
                    p
                } else {
                    p.next_power_of_two() / 2
                };
                let r = p - q;
                (0..q)
                    .map(|c| {
                        let mut steps = Vec::new();
                        // fold-in pre-steps, rotated per chunk so the
                        // r independent folds spread across rounds
                        for i in 0..r {
                            let j = (c + i) % r;
                            steps.push((j, q + j));
                        }
                        // recursive halving among the q survivors:
                        // at level d (q/2, q/4, …, 1) every rank whose
                        // bit d disagrees with chunk index c ships its
                        // chunk-c partial to the partner rank ^ d —
                        // after the last level rank c holds chunk c.
                        let mut d = q / 2;
                        while d >= 1 {
                            // processed (higher) halving bits must
                            // already match the chunk index
                            let hi_mask = !(2 * d - 1);
                            for rk in 0..q {
                                if (rk & hi_mask) == (c & hi_mask) && (rk & d) != (c & d)
                                {
                                    steps.push((rk ^ d, rk));
                                }
                            }
                            d /= 2;
                        }
                        Chunk { lo: c * m / q, hi: (c + 1) * m / q, steps, root: c }
                    })
                    .collect()
            }
            Topology::PipelinedTree => {
                let steps = tree_steps(p);
                (0..PIPELINE_CHUNKS)
                    .map(|c| Chunk {
                        lo: c * m / PIPELINE_CHUNKS,
                        hi: (c + 1) * m / PIPELINE_CHUNKS,
                        steps: steps.clone(),
                        root: 0,
                    })
                    .collect()
            }
        };
        ReducePlan { p, m, chunks }
    }

    /// Serialized exchange rounds one AllReduce of this topology needs
    /// (reduce + broadcast halves) — the α multiplier of the standard
    /// α–β cost model, and the column the bench's round table reports.
    pub fn alpha_rounds(&self, p: usize) -> usize {
        if p <= 1 {
            return 0;
        }
        let levels = (p.max(2) as f64).log2().ceil() as usize;
        match self {
            Topology::Flat | Topology::Ring => 2 * (p - 1),
            Topology::Tree => 2 * levels,
            Topology::HalvingDoubling => {
                // +2 fold rounds (in + out) when P isn't a power of two
                let q = if p.is_power_of_two() {
                    p
                } else {
                    p.next_power_of_two() / 2
                };
                let fold = if p == q { 0 } else { 2 };
                2 * (q.max(2) as f64).log2().ceil() as usize + fold
            }
            Topology::PipelinedTree => 2 * (levels + PIPELINE_CHUNKS - 1),
        }
    }
}

/// The seed's stride-doubling accumulation order (rank i ← rank i+s) —
/// shared by [`Topology::Tree`] and [`Topology::PipelinedTree`] so the
/// tree stays bit-compatible with the seed implementation.
fn tree_steps(p: usize) -> Vec<(usize, usize)> {
    let mut steps = Vec::new();
    let mut stride = 1;
    while stride < p {
        let mut i = 0;
        while i + stride < p {
            steps.push((i, i + stride));
            i += stride * 2;
        }
        stride *= 2;
    }
    steps
}

/// Estimated wall time of one AllReduce under the standard α–β model:
/// `α · rounds + β · bytes_on_the_busiest_rank`. `alpha_ns` is the
/// per-exchange latency, `beta_ns_per_byte` the inverse bandwidth —
/// either measured by the mesh link probe (`topology = "auto"` under
/// the p2p plane) or synthesized from the simulated `CostModel`
/// parameters when no mesh exists. Per-rank bytes come from the exact
/// compiled schedule, so the β term reflects what the wire really
/// carries (frame headers included).
pub fn estimate_allreduce_ns(
    alpha_ns: f64,
    beta_ns_per_byte: f64,
    p: usize,
    m: usize,
    topo: Topology,
) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let plan = topo.plan(p, m);
    let busiest = (0..p)
        .map(|r| plan.rank_schedule(r).send_bytes())
        .max()
        .unwrap_or(0) as f64;
    alpha_ns * topo.alpha_rounds(p) as f64 + beta_ns_per_byte * busiest
}

/// Fit the (α, β) link parameters from two timed tree-plan allreduces
/// (the `topology = "auto"` mesh probe): solving
/// `t(m) = α·rounds + β·busiest_bytes(m)` at the probe's small and
/// large sizes gives β from the slope and α from the small-size
/// intercept. Estimates are clamped non-negative (α to ≥ 1 ns) so a
/// noisy probe can never produce a nonsensical cost model — at worst
/// the fit degenerates toward pure-latency or pure-bandwidth and the
/// chooser falls back to a reasonable family.
pub fn fit_link_params(
    p: usize,
    small_m: usize,
    large_m: usize,
    small_ns: f64,
    large_ns: f64,
) -> (f64, f64) {
    let rounds = Topology::Tree.alpha_rounds(p).max(1) as f64;
    let busiest = |m: usize| -> f64 {
        let plan = Topology::Tree.plan(p, m);
        (0..p)
            .map(|r| plan.rank_schedule(r).send_bytes())
            .max()
            .unwrap_or(0) as f64
    };
    let (b_s, b_l) = (busiest(small_m), busiest(large_m));
    let beta = if b_l > b_s {
        ((large_ns - small_ns) / (b_l - b_s)).max(0.0)
    } else {
        0.0
    };
    let alpha = ((small_ns - beta * b_s) / rounds).max(1.0);
    (alpha, beta)
}

/// The `topology = "auto"` decision rule: pick the plan family with the
/// lowest α–β estimate for this (P, m). Ties break toward the earlier
/// entry of [`Topology::all`] (flat < tree < ring < hd < ptree), which
/// keeps the choice deterministic.
pub fn choose_topology(alpha_ns: f64, beta_ns_per_byte: f64, p: usize, m: usize) -> Topology {
    let mut best = Topology::Tree;
    let mut best_ns = f64::INFINITY;
    for topo in Topology::all() {
        let est = estimate_allreduce_ns(alpha_ns, beta_ns_per_byte, p, m, topo);
        if est < best_ns {
            best = topo;
            best_ns = est;
        }
    }
    best
}

/// One contiguous index range reduced by an ordered step list; the
/// chunk's sum ends up at `root`.
#[derive(Clone, Debug)]
pub struct Chunk {
    pub lo: usize,
    pub hi: usize,
    /// ordered `dst += src` accumulations over the [lo, hi) range
    pub steps: Vec<(usize, usize)>,
    pub root: usize,
}

/// A full deterministic reduction schedule.
#[derive(Clone, Debug)]
pub struct ReducePlan {
    pub p: usize,
    pub m: usize,
    pub chunks: Vec<Chunk>,
}

impl ReducePlan {
    /// Vector hops the schedule moves (in units of full m-vectors) —
    /// the *logical* traffic, used by the measured-traffic report.
    pub fn vector_hops(&self) -> f64 {
        let m = self.m.max(1) as f64;
        self.chunks
            .iter()
            .map(|c| c.steps.len() as f64 * (c.hi - c.lo) as f64 / m)
            .sum()
    }

    /// Compile the plan into per-rank peer-to-peer schedules for a full
    /// AllReduce: the plan's accumulation steps become matched
    /// `Send`/`RecvAccum` pairs (reduce half), then the step list is
    /// mirrored in reverse as `Send`/`RecvCopy` pairs so every rank ends
    /// holding the reduced vector (broadcast half — the §4.1 tree's
    /// mirrored downward broadcast, the ring's allgather rotation).
    ///
    /// Guarantees the data plane relies on:
    ///
    /// * **Bitwise identity with [`reduce`]**: each rank applies its
    ///   accumulations in the plan's step order over each chunk range,
    ///   and a rank only sends a range after applying every accumulation
    ///   that precedes that step in the plan — so the summation order is
    ///   exactly the plan's, element for element.
    /// * **Deadlock freedom**: ops are grouped into rounds (the step
    ///   index within each chunk); within a round every rank's sends
    ///   precede its receives, and for any pair of ranks both sides see
    ///   their mutual ops in the same relative order (both schedules are
    ///   filtered from one global emission sequence), so per-connection
    ///   FIFO delivery matches each blocking receive to the right frame.
    /// * **Degeneration**: P = 1 and empty chunk ranges (m < P leaves
    ///   ring chunks with `lo == hi`) produce no ops at all.
    pub fn rank_schedules(&self) -> Vec<RankSchedule> {
        (0..self.p).map(|rank| self.rank_schedule(rank)).collect()
    }

    /// One rank's slice of [`ReducePlan::rank_schedules`], compiled
    /// without materializing the other P − 1 — what the mesh executor
    /// compiles (once per `(topology, m)`, cached by the worker). The
    /// per-rank op order is identical to filtering the joint schedule,
    /// which is what the pairing and ordering guarantees above rely on.
    pub fn rank_schedule(&self, rank: usize) -> RankSchedule {
        let mut ops = Vec::new();
        let rounds = self.chunks.iter().map(|c| c.steps.len()).max().unwrap_or(0);
        // reduce half: plan step k of every chunk is round k
        for round in 0..rounds {
            for ch in &self.chunks {
                if ch.hi <= ch.lo {
                    continue;
                }
                if let Some(&(dst, src)) = ch.steps.get(round) {
                    if src == rank {
                        ops.push(MeshOp::Send { to: dst, lo: ch.lo, hi: ch.hi });
                    }
                }
            }
            for ch in &self.chunks {
                if ch.hi <= ch.lo {
                    continue;
                }
                if let Some(&(dst, src)) = ch.steps.get(round) {
                    if dst == rank {
                        ops.push(MeshOp::RecvAccum { from: src, lo: ch.lo, hi: ch.hi });
                    }
                }
            }
        }
        // broadcast half: mirror the steps in reverse — step k's dst
        // already holds the final chunk value when its mirror comes up
        // (it received it from a mirror step with a larger k earlier)
        for round in 0..rounds {
            for ch in &self.chunks {
                if ch.hi <= ch.lo || round >= ch.steps.len() {
                    continue;
                }
                let (dst, src) = ch.steps[ch.steps.len() - 1 - round];
                if dst == rank {
                    ops.push(MeshOp::Send { to: src, lo: ch.lo, hi: ch.hi });
                }
            }
            for ch in &self.chunks {
                if ch.hi <= ch.lo || round >= ch.steps.len() {
                    continue;
                }
                let (dst, src) = ch.steps[ch.steps.len() - 1 - round];
                if src == rank {
                    ops.push(MeshOp::RecvCopy { from: dst, lo: ch.lo, hi: ch.hi });
                }
            }
        }
        RankSchedule { rank, ops }
    }
}

/// One data-plane action in a rank's compiled AllReduce schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MeshOp {
    /// Send the current `[lo, hi)` of the local buffer to rank `to`.
    Send { to: usize, lo: usize, hi: usize },
    /// Receive `[lo, hi)` from rank `from` and accumulate (`buf += recv`).
    RecvAccum { from: usize, lo: usize, hi: usize },
    /// Receive `[lo, hi)` from rank `from`, overwriting (broadcast half).
    RecvCopy { from: usize, lo: usize, hi: usize },
}

/// One rank's compiled peer-to-peer schedule.
#[derive(Clone, Debug)]
pub struct RankSchedule {
    pub rank: usize,
    pub ops: Vec<MeshOp>,
}

impl RankSchedule {
    /// Elements this rank puts on the wire executing the schedule.
    pub fn send_elems(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                MeshOp::Send { lo, hi, .. } => hi - lo,
                _ => 0,
            })
            .sum()
    }

    /// Send frames this rank emits executing the schedule.
    pub fn send_frames(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, MeshOp::Send { .. }))
            .count()
    }

    /// Exact mesh bytes this rank puts on the wire for one AllReduce:
    /// each frame is a 4-byte length prefix plus raw f64 payload.
    pub fn send_bytes(&self) -> u64 {
        8 * self.send_elems() as u64 + 4 * self.send_frames() as u64
    }
}

impl ReducePlan {
    /// Exact total mesh bytes one AllReduce of this plan moves over the
    /// p2p data plane (summed across ranks, counted once at each
    /// sender) — the deterministic counts `net_smoke`'s byte report and
    /// the parity tests pin, and the per-topology table in
    /// `net/README.md`.
    pub fn mesh_bytes(&self) -> u64 {
        (0..self.p).map(|r| self.rank_schedule(r).send_bytes()).sum()
    }

    /// Per-op compute/communication-overlap flags for `rank`'s compiled
    /// schedule, aligned index-for-index with
    /// [`ReducePlan::rank_schedule`]`(rank).ops`.
    ///
    /// A `Send` is *streamable* when the range it ships is still a pure
    /// local partial — no earlier receive in the rank's schedule
    /// overlaps `[lo, hi)` — so the sender may stream it as per-block
    /// partial frames while later blocks are still computing (one
    /// streamed send per destination: frames of a second streamed range
    /// would interleave with the first on the same connection). A
    /// receive is streamable exactly when its matching peer send is:
    /// per-connection FIFO pairs the k-th receive-from-X here with the
    /// k-th send-to-`rank` in X's schedule, so both sides derive the
    /// same verdict from the plan alone — no negotiation on the wire.
    pub fn overlap_flags(&self, rank: usize) -> Vec<bool> {
        use std::collections::BTreeMap;
        let sched = self.rank_schedule(rank);
        let mut flags = streamable_sends(&sched.ops);
        // peer → stream flags of its sends addressed to us, in order
        let mut peer_sends: BTreeMap<usize, Vec<bool>> = BTreeMap::new();
        let mut recv_seen: BTreeMap<usize, usize> = BTreeMap::new();
        for (k, op) in sched.ops.iter().enumerate() {
            let from = match *op {
                MeshOp::RecvAccum { from, .. } | MeshOp::RecvCopy { from, .. } => from,
                MeshOp::Send { .. } => continue,
            };
            let to_me = peer_sends.entry(from).or_insert_with(|| {
                let peer = self.rank_schedule(from);
                streamable_sends(&peer.ops)
                    .into_iter()
                    .zip(&peer.ops)
                    .filter_map(|(f, op)| match *op {
                        MeshOp::Send { to, .. } if to == rank => Some(f),
                        _ => None,
                    })
                    .collect()
            });
            let idx = recv_seen.entry(from).or_insert(0);
            flags[k] = to_me.get(*idx).copied().unwrap_or(false);
            *idx += 1;
        }
        flags
    }
}

/// The sender half of [`ReducePlan::overlap_flags`]: which `Send` ops
/// ship a pure local partial (no earlier receive overlapping the
/// range), deduplicated to the first per destination.
fn streamable_sends(ops: &[MeshOp]) -> Vec<bool> {
    let mut streamed_to: Vec<usize> = Vec::new();
    let mut flags = vec![false; ops.len()];
    for (k, op) in ops.iter().enumerate() {
        let MeshOp::Send { to, lo, hi } = *op else { continue };
        let touched = ops[..k].iter().any(|prev| match *prev {
            MeshOp::RecvAccum { lo: plo, hi: phi, .. }
            | MeshOp::RecvCopy { lo: plo, hi: phi, .. } => plo < hi && lo < phi,
            MeshOp::Send { .. } => false,
        });
        if !touched && !streamed_to.contains(&to) {
            streamed_to.push(to);
            flags[k] = true;
        }
    }
    flags
}

/// Reference executor for per-rank schedules: runs every rank's ops
/// against in-memory FIFO queues (one per directed rank pair — exactly
/// the ordering a TCP connection provides) and returns each rank's
/// final buffer. Used by the property tests to pin the p2p schedules
/// against the flat [`reduce`] execution, and doubling as a deadlock
/// detector: a stalled schedule panics instead of hanging.
pub fn simulate_schedules(parts: &[Vec<f64>], plan: &ReducePlan) -> Vec<Vec<f64>> {
    simulate_schedules_counting(parts, plan).0
}

/// [`simulate_schedules`] plus the exact per-rank wire bytes the run
/// enqueued (4-byte length prefix + 8-byte f64 payload per frame, the
/// p2p data plane's framing) — what the property tests pin against
/// [`RankSchedule::send_bytes`] and [`ReducePlan::mesh_bytes`].
pub fn simulate_schedules_counting(
    parts: &[Vec<f64>],
    plan: &ReducePlan,
) -> (Vec<Vec<f64>>, Vec<u64>) {
    use std::collections::{BTreeMap, VecDeque};
    assert_eq!(parts.len(), plan.p, "parts/plan rank mismatch");
    let scheds = plan.rank_schedules();
    let mut bufs: Vec<Vec<f64>> = parts.to_vec();
    let mut sent_bytes: Vec<u64> = vec![0; plan.p];
    let mut queues: BTreeMap<(usize, usize), VecDeque<Vec<f64>>> = BTreeMap::new();
    let mut next: Vec<usize> = vec![0; plan.p];
    loop {
        let mut progressed = false;
        let mut done = true;
        for r in 0..plan.p {
            // drain every op this rank can execute right now
            while let Some(op) = scheds[r].ops.get(next[r]) {
                match *op {
                    MeshOp::Send { to, lo, hi } => {
                        let frame = bufs[r][lo..hi].to_vec();
                        sent_bytes[r] += 8 * frame.len() as u64 + 4;
                        queues.entry((r, to)).or_default().push_back(frame);
                    }
                    MeshOp::RecvAccum { from, lo, hi } => {
                        let Some(frame) =
                            queues.entry((from, r)).or_default().pop_front()
                        else {
                            break;
                        };
                        assert_eq!(frame.len(), hi - lo, "frame/range mismatch");
                        linalg::accum(&mut bufs[r][lo..hi], &frame);
                    }
                    MeshOp::RecvCopy { from, lo, hi } => {
                        let Some(frame) =
                            queues.entry((from, r)).or_default().pop_front()
                        else {
                            break;
                        };
                        assert_eq!(frame.len(), hi - lo, "frame/range mismatch");
                        bufs[r][lo..hi].copy_from_slice(&frame);
                    }
                }
                next[r] += 1;
                progressed = true;
            }
            if next[r] < scheds[r].ops.len() {
                done = false;
            }
        }
        if done {
            break;
        }
        assert!(progressed, "schedule deadlock: no rank can progress");
    }
    assert!(
        queues.values().all(VecDeque::is_empty),
        "schedule left undelivered frames"
    );
    (bufs, sent_bytes)
}

fn two_mut<T>(v: &mut [T], i: usize, j: usize) -> (&mut T, &T) {
    assert_ne!(i, j, "reduction step with dst == src");
    if i < j {
        let (a, b) = v.split_at_mut(j);
        (&mut a[i], &b[0])
    } else {
        let (a, b) = v.split_at_mut(i);
        (&mut b[0], &a[j])
    }
}

/// Execute a reduction plan over per-rank parts. The summation order is
/// exactly the plan's step order, so the result is a pure function of
/// (parts, plan) — no threading, no transport dependence.
pub fn reduce(mut parts: Vec<Vec<f64>>, plan: &ReducePlan) -> Vec<f64> {
    assert_eq!(parts.len(), plan.p, "parts/plan rank mismatch");
    let m = parts[0].len();
    assert!(
        parts.iter().all(|v| v.len() == m),
        "ragged parts in reduction"
    );
    assert_eq!(m, plan.m, "parts/plan length mismatch");
    let mut out = vec![0.0; m];
    for ch in &plan.chunks {
        if ch.hi <= ch.lo {
            continue;
        }
        for &(dst, src) in &ch.steps {
            let (d, s) = two_mut(&mut parts, dst, src);
            linalg::accum(&mut d[ch.lo..ch.hi], &s[ch.lo..ch.hi]);
        }
        out[ch.lo..ch.hi].copy_from_slice(&parts[ch.root][ch.lo..ch.hi]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn int_parts(p: usize, m: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        (0..p)
            .map(|_| (0..m).map(|_| rng.below(41) as f64 - 20.0).collect())
            .collect()
    }

    fn naive_sum(parts: &[Vec<f64>]) -> Vec<f64> {
        let m = parts[0].len();
        let mut out = vec![0.0; m];
        for part in parts {
            for j in 0..m {
                out[j] += part[j];
            }
        }
        out
    }

    #[test]
    fn all_topologies_sum_exactly() {
        for topo in Topology::all() {
            for p in 1..=9 {
                for m in [1usize, 2, 5, 16, 33] {
                    let parts = int_parts(p, m, 7 * p as u64 + m as u64);
                    let want = naive_sum(&parts);
                    let got = reduce(parts, &topo.plan(p, m));
                    assert_eq!(got, want, "{topo:?} p={p} m={m}");
                }
            }
        }
    }

    #[test]
    fn tree_matches_seed_stride_doubling() {
        // reference: the seed's in-place tree loop
        let p = 7;
        let m = 13;
        let mut parts = int_parts(p, m, 42);
        // perturb to non-integers so order matters
        for (i, part) in parts.iter_mut().enumerate() {
            for (j, v) in part.iter_mut().enumerate() {
                *v += 1e-13 * ((i * 31 + j) as f64);
            }
        }
        let mut legacy = parts.clone();
        let mut stride = 1;
        while stride < legacy.len() {
            let mut i = 0;
            while i + stride < legacy.len() {
                let (lo, hi) = legacy.split_at_mut(i + stride);
                crate::linalg::accum(&mut lo[i], &hi[0]);
                i += stride * 2;
            }
            stride *= 2;
        }
        let want = legacy.swap_remove(0);
        let got = reduce(parts, &Topology::Tree.plan(p, m));
        assert!(
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "tree plan diverged from the seed summation order"
        );
    }

    #[test]
    fn reduce_is_bitwise_deterministic() {
        for topo in Topology::all() {
            let mut rng = crate::util::rng::Pcg64::new(9);
            let parts: Vec<Vec<f64>> =
                (0..5).map(|_| (0..17).map(|_| rng.normal()).collect()).collect();
            let plan = topo.plan(5, 17);
            let a = reduce(parts.clone(), &plan);
            let b = reduce(parts, &plan);
            assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn ring_handles_short_vectors() {
        // m < P leaves some chunks empty — the sum must still be exact
        let parts = int_parts(6, 3, 3);
        let want = naive_sum(&parts);
        assert_eq!(reduce(parts, &Topology::Ring.plan(6, 3)), want);
    }

    #[test]
    fn single_rank_is_identity() {
        for topo in Topology::all() {
            let parts = vec![vec![1.5, -2.5, 3.0]];
            assert_eq!(
                reduce(parts.clone(), &topo.plan(1, 3)),
                parts[0],
                "{topo:?}"
            );
        }
    }

    #[test]
    fn vector_hops_ordering() {
        // flat moves P−1 full vectors; tree the same count but fewer
        // serialized rounds; ring moves (P−1)/P per chunk × P chunks.
        let p = 8;
        let m = 64;
        let flat = Topology::Flat.plan(p, m).vector_hops();
        let tree = Topology::Tree.plan(p, m).vector_hops();
        let ring = Topology::Ring.plan(p, m).vector_hops();
        assert_eq!(flat, (p - 1) as f64);
        assert_eq!(tree, (p - 1) as f64);
        // P chunks × (P−1) steps × m/P elements each = P−1 full vectors
        assert!((ring - (p - 1) as f64).abs() < 1e-12, "ring hops {ring}");
    }

    #[test]
    fn schedules_allreduce_bitwise_matches_plan_reduce() {
        for topo in Topology::all() {
            for p in 1..=8 {
                for m in [1usize, 3, 5, 16, 33] {
                    let mut parts = int_parts(p, m, 11 * p as u64 + m as u64);
                    // perturb so summation order matters
                    for (i, part) in parts.iter_mut().enumerate() {
                        for (j, v) in part.iter_mut().enumerate() {
                            *v += 1e-13 * ((i * 17 + j) as f64);
                        }
                    }
                    let plan = topo.plan(p, m);
                    let want = reduce(parts.clone(), &plan);
                    let bufs = simulate_schedules(&parts, &plan);
                    for (rank, buf) in bufs.iter().enumerate() {
                        assert!(
                            buf.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                            "{topo:?} p={p} m={m} rank={rank} diverged"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn single_rank_schedule_is_a_noop() {
        for topo in Topology::all() {
            let scheds = topo.plan(1, 7).rank_schedules();
            assert_eq!(scheds.len(), 1, "{topo:?}");
            assert!(scheds[0].ops.is_empty(), "{topo:?}: {:?}", scheds[0].ops);
        }
    }

    #[test]
    fn empty_ring_chunks_produce_no_ops() {
        // m < P: some ring chunks are empty; no zero-length frames
        let scheds = Topology::Ring.plan(6, 3).rank_schedules();
        for s in &scheds {
            for op in &s.ops {
                let (lo, hi) = match *op {
                    MeshOp::Send { lo, hi, .. }
                    | MeshOp::RecvAccum { lo, hi, .. }
                    | MeshOp::RecvCopy { lo, hi, .. } => (lo, hi),
                };
                assert!(hi > lo, "zero-length op {op:?}");
            }
        }
    }

    #[test]
    fn sends_and_recvs_pair_up() {
        for topo in Topology::all() {
            for (p, m) in [(2usize, 4usize), (4, 4), (5, 16), (8, 3)] {
                let scheds = topo.plan(p, m).rank_schedules();
                let mut sends = 0usize;
                let mut recvs = 0usize;
                let mut sent_elems = 0usize;
                for s in &scheds {
                    for op in &s.ops {
                        match *op {
                            MeshOp::Send { to, .. } => {
                                assert!(to < p);
                                assert_ne!(to, s.rank, "self-send");
                                sends += 1;
                            }
                            MeshOp::RecvAccum { from, .. }
                            | MeshOp::RecvCopy { from, .. } => {
                                assert!(from < p);
                                assert_ne!(from, s.rank, "self-recv");
                                recvs += 1;
                            }
                        }
                    }
                    sent_elems += s.send_elems();
                }
                assert_eq!(sends, recvs, "{topo:?} p={p} m={m}");
                // reduce + mirrored broadcast: twice the plan's hops
                let expect = 2.0 * topo.plan(p, m).vector_hops() * m as f64;
                assert_eq!(sent_elems as f64, expect, "{topo:?} p={p} m={m}");
            }
        }
    }

    #[test]
    fn mesh_bytes_match_schedule_sends() {
        for topo in Topology::all() {
            for (p, m) in [(1usize, 5usize), (4, 60), (6, 3), (5, 17)] {
                let plan = topo.plan(p, m);
                let want: u64 = plan
                    .rank_schedules()
                    .iter()
                    .map(|s| 8 * s.send_elems() as u64 + 4 * s.send_frames() as u64)
                    .sum();
                assert_eq!(plan.mesh_bytes(), want, "{topo:?} p={p} m={m}");
            }
        }
        // the README's P = 4, m = 60 table: flat/tree 6 × (4 + 480),
        // ring/hd/ptree 24 × (4 + 120)
        assert_eq!(Topology::Flat.plan(4, 60).mesh_bytes(), 6 * 484);
        assert_eq!(Topology::Tree.plan(4, 60).mesh_bytes(), 6 * 484);
        assert_eq!(Topology::Ring.plan(4, 60).mesh_bytes(), 24 * 124);
        assert_eq!(Topology::HalvingDoubling.plan(4, 60).mesh_bytes(), 24 * 124);
        assert_eq!(Topology::PipelinedTree.plan(4, 60).mesh_bytes(), 24 * 124);
        // …and the P = 6 column (q = 4 survivors + 2 folded ranks for
        // hd: 20 chunk steps of 15 elements; ring: 60 frames of 10)
        assert_eq!(Topology::Flat.plan(6, 60).mesh_bytes(), 10 * 484);
        assert_eq!(Topology::Tree.plan(6, 60).mesh_bytes(), 10 * 484);
        assert_eq!(Topology::Ring.plan(6, 60).mesh_bytes(), 60 * 84);
        assert_eq!(Topology::HalvingDoubling.plan(6, 60).mesh_bytes(), 40 * 124);
        assert_eq!(Topology::PipelinedTree.plan(6, 60).mesh_bytes(), 40 * 124);
        // P = 1 is a no-op on every topology
        for topo in Topology::all() {
            assert_eq!(topo.plan(1, 9).mesh_bytes(), 0, "{topo:?}");
        }
    }

    #[test]
    fn hd_per_rank_bytes_are_uniform_and_bandwidth_optimal() {
        // every hd rank moves exactly 2·(P−1)/P·m elements — the
        // allreduce bandwidth lower bound the ring also achieves; the
        // win over the ring is rounds (2·log₂P vs 2·(P−1)), not bytes
        let plan = Topology::HalvingDoubling.plan(4, 60);
        for r in 0..4 {
            let s = plan.rank_schedule(r);
            assert_eq!(s.send_elems(), 90, "rank {r}"); // 2·(3/4)·60
            assert_eq!(s.send_frames(), 6, "rank {r}");
            assert_eq!(s.send_bytes(), 744, "rank {r}");
        }
        // the flat/tree busiest rank moves a full vector per hop: the
        // hd busiest rank carries 0.51×/0.77× of that at P = 4
        let flat_max = (0..4)
            .map(|r| Topology::Flat.plan(4, 60).rank_schedule(r).send_bytes())
            .max()
            .unwrap();
        let tree_max = (0..4)
            .map(|r| Topology::Tree.plan(4, 60).rank_schedule(r).send_bytes())
            .max()
            .unwrap();
        assert_eq!(flat_max, 3 * 484);
        assert_eq!(tree_max, 2 * 484);
    }

    #[test]
    fn hd_folds_non_power_of_two_ranks() {
        // P = 6: ranks 4 and 5 fold into survivors 0 and 1, appear in
        // no halving step, and still end up with the full reduced
        // vector via the mirrored broadcast
        let plan = Topology::HalvingDoubling.plan(6, 60);
        for folded in [4usize, 5] {
            let sched = plan.rank_schedule(folded);
            let reduce_sends = sched
                .ops
                .iter()
                .take_while(|op| matches!(op, MeshOp::Send { .. }))
                .count();
            // the fold: its whole vector leaves as q = 4 chunk frames
            assert_eq!(reduce_sends, 4, "rank {folded}");
            let copies = sched
                .ops
                .iter()
                .filter(|op| matches!(op, MeshOp::RecvCopy { .. }))
                .count();
            assert_eq!(copies, 4, "rank {folded} fold-out");
        }
        // integer exactness at every non-power-of-two P
        for p in [3usize, 5, 6, 7, 9] {
            for m in [1usize, 3, 60] {
                let parts = int_parts(p, m, 5 * p as u64 + m as u64);
                let want = naive_sum(&parts);
                assert_eq!(
                    reduce(parts, &Topology::HalvingDoubling.plan(p, m)),
                    want,
                    "p={p} m={m}"
                );
            }
        }
    }

    #[test]
    fn ptree_splits_tree_into_pipeline_chunks() {
        let plan = Topology::PipelinedTree.plan(4, 60);
        assert_eq!(plan.chunks.len(), PIPELINE_CHUNKS);
        let tree = Topology::Tree.plan(4, 60);
        for ch in &plan.chunks {
            assert_eq!(ch.steps, tree.chunks[0].steps);
            assert_eq!(ch.root, 0);
            assert_eq!(ch.hi - ch.lo, 60 / PIPELINE_CHUNKS);
        }
        // m < C leaves trailing chunks empty — still exact, no ops
        let short = Topology::PipelinedTree.plan(5, 2);
        for s in short.rank_schedules() {
            for op in &s.ops {
                let (lo, hi) = match *op {
                    MeshOp::Send { lo, hi, .. }
                    | MeshOp::RecvAccum { lo, hi, .. }
                    | MeshOp::RecvCopy { lo, hi, .. } => (lo, hi),
                };
                assert!(hi > lo, "zero-length op {op:?}");
            }
        }
    }

    #[test]
    fn simulate_counts_exact_wire_bytes() {
        for topo in Topology::all() {
            for (p, m) in [(1usize, 5usize), (2, 4), (4, 60), (5, 17), (6, 3), (8, 8)] {
                let parts = int_parts(p, m, 13 * p as u64 + m as u64);
                let plan = topo.plan(p, m);
                let (_, sent) = simulate_schedules_counting(&parts, &plan);
                for (r, &bytes) in sent.iter().enumerate() {
                    assert_eq!(
                        bytes,
                        plan.rank_schedule(r).send_bytes(),
                        "{topo:?} p={p} m={m} rank={r}"
                    );
                }
                assert_eq!(
                    sent.iter().sum::<u64>(),
                    plan.mesh_bytes(),
                    "{topo:?} p={p} m={m}"
                );
            }
        }
    }

    #[test]
    fn parse_accepts_aliases_and_rejects_unknown() {
        for (alias, want) in [
            ("flat", Topology::Flat),
            ("tree", Topology::Tree),
            ("ring", Topology::Ring),
            ("hd", Topology::HalvingDoubling),
            ("halving_doubling", Topology::HalvingDoubling),
            ("halving-doubling", Topology::HalvingDoubling),
            ("ptree", Topology::PipelinedTree),
            ("pipelined_tree", Topology::PipelinedTree),
            ("pipelined-tree", Topology::PipelinedTree),
            ("HD", Topology::HalvingDoubling),
        ] {
            assert_eq!(Topology::parse(alias), Ok(want), "{alias}");
        }
        let err = Topology::parse("mesh").unwrap_err();
        for name in ["flat", "tree", "ring", "hd", "ptree"] {
            assert!(err.contains(name), "error {err:?} misses {name}");
        }
    }

    #[test]
    fn alpha_rounds_pin_the_round_table() {
        // P = 4: hd needs 4 serialized exchange levels, the ring 6 —
        // the round win that motivates hd (bytes are tied, see
        // hd_per_rank_bytes_are_uniform_and_bandwidth_optimal)
        assert_eq!(Topology::Flat.alpha_rounds(4), 6);
        assert_eq!(Topology::Tree.alpha_rounds(4), 4);
        assert_eq!(Topology::Ring.alpha_rounds(4), 6);
        assert_eq!(Topology::HalvingDoubling.alpha_rounds(4), 4);
        assert_eq!(
            Topology::PipelinedTree.alpha_rounds(4),
            2 * (2 + PIPELINE_CHUNKS - 1)
        );
        // non-power-of-two P pays the fold-in/fold-out round pair
        assert_eq!(Topology::HalvingDoubling.alpha_rounds(6), 6);
        assert_eq!(Topology::Ring.alpha_rounds(6), 10);
        // P = 1 is free everywhere
        for topo in Topology::all() {
            assert_eq!(topo.alpha_rounds(1), 0, "{topo:?}");
        }
    }

    #[test]
    fn fit_recovers_synthetic_link_params() {
        // generate the two probe timings from a known (α, β) and check
        // the fit inverts them exactly
        let (p, small_m, large_m) = (4usize, 16usize, 65_536usize);
        let (alpha, beta) = (5_000.0, 2.0);
        let rounds = Topology::Tree.alpha_rounds(p) as f64;
        let busiest = |m: usize| {
            let plan = Topology::Tree.plan(p, m);
            (0..p)
                .map(|r| plan.rank_schedule(r).send_bytes())
                .max()
                .unwrap() as f64
        };
        let t_s = alpha * rounds + beta * busiest(small_m);
        let t_l = alpha * rounds + beta * busiest(large_m);
        let (a, b) = fit_link_params(p, small_m, large_m, t_s, t_l);
        assert!((a - alpha).abs() < 1e-6, "alpha {a}");
        assert!((b - beta).abs() < 1e-9, "beta {b}");
        // clamps: a probe where the large size came back faster (noise)
        // still yields non-negative β and a positive α
        let (a, b) = fit_link_params(p, small_m, large_m, 10_000.0, 5_000.0);
        assert_eq!(b, 0.0);
        assert!(a > 0.0);
        // degenerate single-rank probe
        let (a, b) = fit_link_params(1, small_m, large_m, 0.0, 0.0);
        assert!(a >= 1.0);
        assert_eq!(b, 0.0);
    }

    #[test]
    fn auto_choice_follows_the_alpha_beta_model() {
        // bandwidth-dominated (large m, cheap latency): hd ties ring on
        // bytes but needs fewer rounds, so hd wins
        let big = choose_topology(10_000.0, 1.0, 8, 600_000);
        assert_eq!(big, Topology::HalvingDoubling);
        // latency-dominated (tiny m, expensive latency): the round
        // count decides, so the 2·log₂P families win over the ring
        let small = choose_topology(1_000_000.0, 1.0, 8, 4);
        assert!(
            matches!(small, Topology::Tree | Topology::HalvingDoubling),
            "{small:?}"
        );
        assert_ne!(small, Topology::Ring);
        // the choice is never worse than any fixed family
        for (p, m) in [(4usize, 60usize), (4, 6_000), (6, 600_000), (8, 60)] {
            let chosen = choose_topology(5_000.0, 0.5, p, m);
            let est = estimate_allreduce_ns(5_000.0, 0.5, p, m, chosen);
            for topo in Topology::all() {
                assert!(
                    est <= estimate_allreduce_ns(5_000.0, 0.5, p, m, topo),
                    "auto {chosen:?} worse than {topo:?} at p={p} m={m}"
                );
            }
        }
    }

    #[test]
    fn weighted_combine_schedules_match_flat_weighted_sum() {
        // the combine plane's pre-transform (per-rank weights, incl.
        // zero weights) followed by the compiled schedules must land
        // every rank on exactly the bits of the driver-style weighted
        // sum (scale each part, then plan-reduce) — for m < P, m ∤ P
        // and P = 1
        let mut rng = crate::util::rng::Pcg64::new(0xC0DE);
        for topo in Topology::all() {
            for (p, m) in [(1usize, 4usize), (4, 60), (6, 3), (5, 17), (7, 20)] {
                let parts: Vec<Vec<f64>> = (0..p)
                    .map(|_| (0..m).map(|_| rng.normal()).collect())
                    .collect();
                let weights: Vec<f64> = (0..p)
                    .map(|r| if r % 3 == 2 { 0.0 } else { 0.25 + 0.5 * rng.normal().abs() })
                    .collect();
                let scaled: Vec<Vec<f64>> = parts
                    .iter()
                    .zip(&weights)
                    .map(|(v, &w)| {
                        let mut v = v.clone();
                        crate::linalg::scale(w, &mut v);
                        v
                    })
                    .collect();
                let plan = topo.plan(p, m);
                let want = reduce(scaled.clone(), &plan);
                for (rank, buf) in simulate_schedules(&scaled, &plan).iter().enumerate()
                {
                    assert!(
                        buf.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{topo:?} p={p} m={m} rank={rank} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn names_roundtrip() {
        for topo in Topology::all() {
            assert_eq!(Topology::from_name(topo.name()), Some(topo));
        }
        assert_eq!(Topology::from_name("mesh"), None);
    }

    #[test]
    fn overlap_flags_flat_streams_every_reduce_leg() {
        let plan = Topology::Flat.plan(4, 60);
        // non-root ranks stream their single reduce-half send; the
        // broadcast copy back never streams
        for rank in 1..4 {
            let sched = plan.rank_schedule(rank);
            let flags = plan.overlap_flags(rank);
            assert_eq!(flags.len(), sched.ops.len());
            assert!(matches!(sched.ops[0], MeshOp::Send { to: 0, .. }));
            assert!(flags[0], "rank {rank} reduce send should stream");
            assert!(!flags[1..].iter().any(|&f| f), "rank {rank} broadcast streamed");
        }
        // the root stages every reduce-half receive; its broadcast
        // sends carry the merged sum and must not stream
        let sched = plan.rank_schedule(0);
        let flags = plan.overlap_flags(0);
        for (k, op) in sched.ops.iter().enumerate() {
            match *op {
                MeshOp::RecvAccum { .. } => assert!(flags[k], "root recv {k} unstaged"),
                _ => assert!(!flags[k], "root op {k} streamed"),
            }
        }
    }

    #[test]
    fn overlap_flags_tree_streams_leaf_sends_only() {
        let plan = Topology::Tree.plan(4, 16);
        // stride-1 leaves (1 and 3) ship pure local partials
        assert!(plan.overlap_flags(1)[0]);
        assert!(plan.overlap_flags(3)[0]);
        // interior rank 2 forwards an already-accumulated range
        let sched = plan.rank_schedule(2);
        let flags = plan.overlap_flags(2);
        for (k, op) in sched.ops.iter().enumerate() {
            match *op {
                MeshOp::RecvAccum { from: 3, .. } => assert!(flags[k]),
                MeshOp::Send { .. } => assert!(!flags[k], "interior send streamed"),
                _ => assert!(!flags[k]),
            }
        }
        // the root stages only the stream arriving from leaf 1
        let sched = plan.rank_schedule(0);
        let flags = plan.overlap_flags(0);
        for (k, op) in sched.ops.iter().enumerate() {
            match *op {
                MeshOp::RecvAccum { from, .. } => assert_eq!(flags[k], from == 1),
                _ => assert!(!flags[k]),
            }
        }
    }

    #[test]
    fn overlap_flags_ring_streams_step_zero_chunks() {
        let plan = Topology::Ring.plan(4, 16);
        for rank in 0..4 {
            let sched = plan.rank_schedule(rank);
            let flags = plan.overlap_flags(rank);
            let streamed_sends: Vec<&MeshOp> = sched
                .ops
                .iter()
                .zip(&flags)
                .filter(|&(op, &f)| f && matches!(op, MeshOp::Send { .. }))
                .map(|(op, _)| op)
                .collect();
            // exactly the rank's own chunk leaves at reduce step 0
            assert_eq!(streamed_sends.len(), 1, "rank {rank}");
            let lo = rank * 4;
            assert!(
                matches!(*streamed_sends[0], MeshOp::Send { to, lo: l, hi }
                    if to == (rank + 1) % 4 && l == lo && hi == lo + 4),
                "rank {rank} streamed {:?}",
                streamed_sends[0]
            );
        }
    }

    #[test]
    fn overlap_flags_are_symmetric_across_connections() {
        use std::collections::BTreeMap;
        for topo in Topology::all() {
            for (p, m) in [(1usize, 5usize), (2, 4), (4, 60), (5, 17), (6, 3), (8, 8)] {
                let plan = topo.plan(p, m);
                // per-connection flag sequences, in wire (FIFO) order
                let mut send_seq: BTreeMap<(usize, usize), Vec<bool>> = BTreeMap::new();
                let mut recv_seq: BTreeMap<(usize, usize), Vec<bool>> = BTreeMap::new();
                for rank in 0..p {
                    let sched = plan.rank_schedule(rank);
                    let flags = plan.overlap_flags(rank);
                    assert_eq!(flags.len(), sched.ops.len(), "{topo:?} p={p} m={m}");
                    let mut received: Vec<(usize, usize)> = Vec::new();
                    for (k, op) in sched.ops.iter().enumerate() {
                        match *op {
                            MeshOp::Send { to, lo, hi } => {
                                if flags[k] {
                                    // a streamed range is a pure local
                                    // partial: nothing merged into it yet
                                    assert!(
                                        !received
                                            .iter()
                                            .any(|&(plo, phi)| plo < hi && lo < phi),
                                        "{topo:?} p={p} m={m} rank={rank} streamed a merged range"
                                    );
                                }
                                send_seq.entry((rank, to)).or_default().push(flags[k]);
                            }
                            MeshOp::RecvAccum { from, lo, hi } => {
                                received.push((lo, hi));
                                recv_seq.entry((from, rank)).or_default().push(flags[k]);
                            }
                            MeshOp::RecvCopy { from, lo, hi } => {
                                assert!(!flags[k], "{topo:?} RecvCopy streamed");
                                received.push((lo, hi));
                                recv_seq.entry((from, rank)).or_default().push(flags[k]);
                            }
                        }
                    }
                }
                // both endpoints of every connection derive the same
                // verdict for every frame — no negotiation needed
                assert_eq!(send_seq, recv_seq, "{topo:?} p={p} m={m}");
                if p > 1 {
                    let streamed = send_seq.values().flatten().filter(|&&f| f).count();
                    assert!(streamed > 0, "{topo:?} p={p} m={m} streams nothing");
                }
            }
        }
    }
}
