//! Length-prefixed binary framing and the message codec (replaces
//! `bincode` + `serde`, in the same spirit as `util::toml` / `util::json`).
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! [ len: u32 ][ payload: len bytes ]
//! payload = [ tag: u8 ][ body ]
//! ```
//!
//! Body primitives:
//!
//! | type      | encoding                                   |
//! |-----------|--------------------------------------------|
//! | `u8/u32/u64` | little-endian fixed width               |
//! | `usize`   | as `u64`                                   |
//! | `f64`     | IEEE-754 bits, little-endian (lossless)    |
//! | `bool`    | one byte, 0/1                              |
//! | `String`  | `u32` length + UTF-8 bytes                 |
//! | `Vec<f64>`| `u64` length + raw f64 bits                |
//! | `Option<f64>` | one flag byte + value if present       |
//!
//! Floats cross the wire as raw bits, so a value decodes to exactly the
//! f64 that was encoded — the property the bitwise-reproducibility
//! tests in `rust/tests/proptest_net.rs` pin down.

use std::io::{Read, Write};

use crate::approx::ApproxKind;
use crate::data::partition::Strategy;
use crate::loss::Loss;

use crate::metrics::telemetry::Span as TelemetrySpan;

use super::{
    Combine, CombineSpec, Command, DataPlane, DualUpdateSpec, FrameEncoding,
    InnerSolveSpec, LocalSolveSpec, Reply, Residency, Topology, VecOp, VecRef,
    WorkerSetup,
};

/// Hard cap on a single frame (guards against corrupt length prefixes).
pub const MAX_FRAME: usize = 1 << 30;

/// Wire protocol version. Carried in `Setup` and echoed in `Ready`, so
/// a stale `worker` binary from an earlier build fails fast at the
/// handshake instead of silently rebuilding a subtly different shard.
/// Bump on ANY change to the frame layout, message tags, field order,
/// or the semantics of the shard-rebuild recipe.
///
/// v2: full-vocabulary transports — `Hvp`, `LossEval`, `LocalSolve`
/// (ADMM/CoCoA/SSZ/feature-FADL payloads), `DualUpdate`, and the
/// `Vector`/`Scalar` replies.
///
/// v3: the peer-to-peer data plane — `Setup` carries the data-plane
/// selection (plane, bind hosts, port base), `Ready` advertises the
/// worker's data-plane port, and the `Mesh`/`MeshOk` handshake plus the
/// `Reduce`/`Reduced` fused phase+AllReduce round trip landed.
///
/// v4: the worker-resident combine plane — commands reference the
/// replicated register file (`VecRef`), `Reduce` carries a
/// `CombineSpec` (per-rank weights, combine kind, store register,
/// requested dots), `Reduced` returns replicated dot products instead
/// of the combined vector, the star plane's `Finish`/`Finished` pair
/// ships plan sums down for the rank-side epilogue, and the
/// `VecOps`/`SetReg`/`FetchReg` commands plus the `Dots` reply landed.
///
/// v5: the intra-worker parallel compute engine — `Setup` carries the
/// worker's `threads` (the persistent block-pool size), `Reply` and
/// `Reduced` report the rank's measured compute seconds (the
/// `meas_compute_secs` trace column), and the `TestAuprc` command
/// (worker-resident held-out scoring, scalar reply) landed.
///
/// v6: the telemetry plane — `Setup` carries the span-recording flag,
/// `Ready` reports the worker's monotonic clock reading (the driver
/// derives per-rank clock offsets for the merged timeline), `Reply`
/// and `Reduced` carry the rank's pool queue-wait nanoseconds (and,
/// for `Reduced`, mesh stall nanoseconds), and the `FetchTelemetry`
/// command / `Telemetry` reply (span-buffer flush, control plane —
/// zero data bytes) landed.
///
/// v7: the serving plane — `Score`/`Scores` (batched CSR scoring: the
/// request carries per-row nnz counts plus flat column/value arrays
/// with f32 values, the reply carries f64 margins tagged with the
/// model epoch they were computed against) and `Publish`/`Published`
/// (hot model swap: new weights in, the freshly published epoch
/// number back). `Score` and `Publish` carry `PROTO_VERSION` right
/// after the tag, like `Setup`/`Ready`, so a stale scorer fails fast
/// at its first request instead of silently mis-decoding a batch.
///
/// v8: the hot-path perf plane — `Setup` carries the SIMD kernel
/// toggle, the compute/communication overlap toggle, and the mesh
/// reduction-frame element encoding (`f64` lossless, or compact `f32`
/// at half the payload bytes); `Reduced` reports the rank's measured
/// overlap nanoseconds (wall time the mesh was draining streamed
/// partials while later row blocks were still computing — the
/// `overlap_secs` trace column). Mesh data-plane frames gained the
/// streamed-range layout (`[len = 4][B: u32]` header + `B` per-block
/// partial frames) used when overlap is on.
///
/// v9: the out-of-core data path — `Setup` carries the shard residency
/// (`ram` | `paged`), the paged buffer budget in MiB, and the prefetch
/// depth; `Reply` and `Reduced` report the rank's page-stall
/// nanoseconds (wall time kernels blocked waiting on a block the
/// prefetcher hadn't loaded yet — the `page_stall_secs` trace column;
/// 0 under ram residency).
///
/// v10: communication-optimal collectives — `Setup` carries the
/// resolved reduction-plan choice (the configured topology name plus
/// the `topology = "auto"` marker), the topology name set grew `hd`
/// (recursive halving-doubling) and `ptree` (chunk-pipelined tree),
/// and the `Probe`/`Probed` pair landed: after the mesh handshake the
/// driver may ask every worker to run a one-shot timed link probe
/// (small + large AllReduce rounds over the already-open mesh), and
/// the reply carries the best measured wall nanoseconds per size —
/// the α/β fit behind the autotuner's per-size-class plan choice.
/// Probe frames are control traffic (zero data bytes).
pub const PROTO_VERSION: u32 = 10;

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Write one `[len][payload]` frame. Returns the bytes put on the wire.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<u64, String> {
    if payload.len() > MAX_FRAME {
        return Err(format!("frame too large: {} bytes", payload.len()));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())
        .and_then(|_| w.write_all(payload))
        .map_err(|e| format!("write frame: {e}"))?;
    Ok(4 + payload.len() as u64)
}

/// Read one frame. `Ok(None)` on a clean EOF at a frame boundary;
/// EOF *inside* the 4-byte length prefix is a truncated stream and
/// reported as an error, not an orderly close.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, String> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(format!(
                    "stream truncated mid frame header ({got}/4 length bytes)"
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(format!("read frame length: {e}")),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(format!("frame length {len} exceeds MAX_FRAME"));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| format!("read frame body ({len} bytes): {e}"))?;
    Ok(Some(payload))
}

// ---------------------------------------------------------------------------
// Primitive encoder / decoder
// ---------------------------------------------------------------------------

/// Appends primitives to a byte buffer.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn vec_f64(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }

    pub fn opt_vec_f64(&mut self, v: Option<&[f64]>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.vec_f64(x);
            }
            None => self.u8(0),
        }
    }

    pub fn vec_u32(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u32(x);
        }
    }

    pub fn vec_vec_u32(&mut self, v: &[Vec<u32>]) {
        self.u64(v.len() as u64);
        for inner in v {
            self.vec_u32(inner);
        }
    }

    /// f32 vector as raw IEEE bits — the serving plane's feature
    /// values ([`crate::linalg::Csr`] stores values as f32).
    pub fn vec_f32(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            put_f32(&mut self.buf, x);
        }
    }
}

/// Append one f32 as little-endian raw IEEE bits — the single element
/// codec shared by the control plane's [`Enc::vec_f32`] (the serving
/// plane's CSR row values) and the mesh data plane's compact
/// [`FrameEncoding::F32`] reduction frames.
#[inline]
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Decode one f32 from its little-endian raw bits — the inverse of
/// [`put_f32`], lossless by construction.
#[inline]
pub fn get_f32(bytes: [u8; 4]) -> f32 {
    f32::from_bits(u32::from_le_bytes(bytes))
}

/// Cursor-based decoder over a frame payload.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "truncated frame: need {n} bytes at offset {} of {}",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize, String> {
        Ok(self.u64()? as usize)
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool(&mut self) -> Result<bool, String> {
        Ok(self.u8()? != 0)
    }

    pub fn str(&mut self) -> Result<String, String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("bad utf-8 string: {e}"))
    }

    pub fn vec_f64(&mut self) -> Result<Vec<f64>, String> {
        let len = self.u64()? as usize;
        if len.saturating_mul(8) > self.buf.len() - self.pos {
            return Err(format!("truncated f64 vector of claimed length {len}"));
        }
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.f64()?);
        }
        Ok(v)
    }

    pub fn opt_f64(&mut self) -> Result<Option<f64>, String> {
        Ok(if self.u8()? == 1 { Some(self.f64()?) } else { None })
    }

    pub fn opt_vec_f64(&mut self) -> Result<Option<Vec<f64>>, String> {
        Ok(if self.u8()? == 1 { Some(self.vec_f64()?) } else { None })
    }

    pub fn vec_u32(&mut self) -> Result<Vec<u32>, String> {
        let len = self.u64()? as usize;
        if len.saturating_mul(4) > self.buf.len() - self.pos {
            return Err(format!("truncated u32 vector of claimed length {len}"));
        }
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    pub fn vec_f32(&mut self) -> Result<Vec<f32>, String> {
        let len = self.u64()? as usize;
        if len.saturating_mul(4) > self.buf.len() - self.pos {
            return Err(format!("truncated f32 vector of claimed length {len}"));
        }
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(get_f32(self.take(4)?.try_into().unwrap()));
        }
        Ok(v)
    }

    pub fn vec_vec_u32(&mut self) -> Result<Vec<Vec<u32>>, String> {
        let len = self.u64()? as usize;
        // each inner vector costs at least its 8-byte length prefix
        if len.saturating_mul(8) > self.buf.len() - self.pos {
            return Err(format!("truncated vector list of claimed length {len}"));
        }
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.vec_u32()?);
        }
        Ok(v)
    }

    pub fn finish(&self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "{} trailing bytes after message body",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Named-enum helpers
// ---------------------------------------------------------------------------

fn strategy_name(s: Strategy) -> &'static str {
    match s {
        Strategy::Contiguous => "contiguous",
        Strategy::RoundRobin => "round_robin",
        Strategy::Random => "random",
    }
}

fn strategy_from(name: &str) -> Result<Strategy, String> {
    match name {
        "contiguous" => Ok(Strategy::Contiguous),
        "round_robin" => Ok(Strategy::RoundRobin),
        "random" => Ok(Strategy::Random),
        other => Err(format!("unknown partition strategy {other:?}")),
    }
}

fn loss_from(name: &str) -> Result<Loss, String> {
    Loss::from_name(name).ok_or_else(|| format!("unknown loss {name:?}"))
}

fn approx_from(name: &str) -> Result<ApproxKind, String> {
    ApproxKind::from_name(name).ok_or_else(|| format!("unknown approximation {name:?}"))
}

fn port_from(v: u32) -> Result<u16, String> {
    u16::try_from(v).map_err(|_| format!("port {v} out of range"))
}

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// Every message either side can send. Driver → worker: `Setup`,
/// `Mesh`, `Cmd`, `Reduce`, `Finish`, `Shutdown`. Worker → driver:
/// `Ready`, `MeshOk`, `Reply`, `Reduced`, `Finished`, `Abort`.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    Setup(WorkerSetup),
    Shutdown,
    /// `data_port` is the worker's bound data-plane listener port
    /// (0 when the star plane is in effect). `now_ns` is the worker's
    /// telemetry clock reading at send time — the driver pairs it with
    /// its own clock at receipt to derive the rank's clock offset for
    /// the merged timeline.
    Ready { m: usize, n: usize, nnz: usize, data_port: u16, now_ns: u64 },
    Abort { msg: String },
    Cmd(Command),
    /// Reply to `Cmd`. `secs` is the rank's measured wall-clock inside
    /// the shard-compute kernel (the `meas_compute_secs` accounting —
    /// the driver takes the max across ranks per phase); `queue_ns` is
    /// the pool queue wait accumulated by the rank's kernel blocks
    /// (the `queue_wait_secs` trace column); `page_ns` is the wall
    /// time kernels blocked on pages still being read (the
    /// `page_stall_secs` column, 0 under ram residency).
    Reply { reply: Reply, secs: f64, queue_ns: u64, page_ns: u64 },
    /// Every rank's advertised data-plane address, rank-indexed; the
    /// worker dials lower ranks, accepts higher ranks, answers `MeshOk`.
    Mesh { addrs: Vec<String> },
    MeshOk,
    /// One-shot link probe (driver → worker, after `MeshOk`, before
    /// the first combine): run `rounds` timed tree-plan AllReduces over
    /// the mesh at `small_m` and at `large_m` elements and report the
    /// best wall time of each. The driver fits per-link (α latency,
    /// β inverse-bandwidth) from the two points and picks the
    /// `topology = "auto"` plan per combine size class. Control
    /// traffic: zero data bytes, charged to the `probe` phase.
    Probe { rounds: u32, small_m: usize, large_m: usize },
    /// Reply to `Probe`: best measured wall nanoseconds for the small
    /// and the large timed AllReduce.
    Probed { small_ns: u64, large_ns: u64 },
    /// Fused phase + combine: execute `cmd`, pre-transform this rank's
    /// reply vectors per `spec`, then — p2p — run the topology plan
    /// over the mesh and complete the combine locally, or — star —
    /// return the pre-transformed parts and await `Finish`.
    Reduce {
        cmd: Command,
        topology: Topology,
        spec: CombineSpec,
    },
    /// Reply to `Reduce`. Under p2p the reply's vector slots are empty
    /// (the combined result lives in the replicated registers) and
    /// `dots` carries the spec's replicated dot products; under star
    /// the slots carry this rank's pre-transformed parts and `dots` is
    /// empty until `Finished`.
    Reduced {
        reply: Reply,
        data_tx: u64,
        data_rx: u64,
        secs: f64,
        /// the rank's measured compute seconds inside the fused phase
        /// (kernel time only — mesh time is `secs`)
        compute_secs: f64,
        /// pool queue wait accumulated by the rank's kernel blocks
        queue_ns: u64,
        /// wall time the rank spent blocked in mesh receives
        stall_ns: u64,
        /// wall time streamed partials were draining onto the mesh
        /// while later row blocks still computed (0 when the
        /// compute/communication overlap is off or ineligible)
        overlap_ns: u64,
        /// wall time kernels blocked waiting on pages still being read
        /// (0 under ram residency)
        page_ns: u64,
        dots: Vec<f64>,
    },
    /// Star-plane combine completion: the driver's plan sums, shipped
    /// back so the rank applies the same epilogue/store the p2p ranks
    /// apply after their mesh schedules.
    Finish { sums: Vec<Vec<f64>> },
    /// Reply to `Finish`: the spec's replicated dot products.
    Finished { dots: Vec<f64> },
    /// Serving plane: score a batch of sparse rows. `cols` is the
    /// feature dimension the client believes the model has (checked
    /// against the served model), `row_nnz[i]` the number of nonzeros
    /// in row `i`, and `col_idx`/`values` the flat concatenation of
    /// every row's (column, value) pairs. Carries `PROTO_VERSION`
    /// after the tag, like `Setup`. `id` is echoed in `Scores`.
    Score {
        id: u64,
        cols: usize,
        row_nnz: Vec<u32>,
        col_idx: Vec<u32>,
        values: Vec<f32>,
    },
    /// Reply to `Score`: `margins[i] = x_i · w` under the model epoch
    /// `epoch` — every reply is attributable to exactly one published
    /// epoch, the hot-swap atomicity contract.
    Scores { id: u64, epoch: u64, margins: Vec<f64> },
    /// Serving plane: atomically publish new weights as the next model
    /// epoch (a retrain landing, or an online-update flush). Carries
    /// `PROTO_VERSION` after the tag.
    Publish { loss: Loss, lambda: f64, weights: Vec<f64> },
    /// Reply to `Publish`: the epoch number the new weights received.
    Published { epoch: u64 },
}

mod tag {
    pub const SETUP: u8 = 1;
    pub const SHUTDOWN: u8 = 2;
    pub const READY: u8 = 3;
    pub const ABORT: u8 = 4;
    pub const MESH: u8 = 5;
    pub const MESH_OK: u8 = 6;
    pub const REDUCE: u8 = 7;
    pub const REDUCED: u8 = 8;
    pub const FINISH: u8 = 9;
    pub const CMD_RESET: u8 = 10;
    pub const CMD_GRAD: u8 = 11;
    pub const CMD_DIRS: u8 = 12;
    pub const CMD_LINESEARCH: u8 = 13;
    pub const CMD_INNER_SOLVE: u8 = 14;
    pub const CMD_WARMSTART: u8 = 15;
    pub const CMD_HVP: u8 = 16;
    pub const CMD_LOSS_EVAL: u8 = 17;
    pub const CMD_LOCAL_SOLVE: u8 = 18;
    pub const CMD_DUAL_UPDATE: u8 = 19;
    pub const CMD_VEC_OPS: u8 = 20;
    pub const CMD_SET_REG: u8 = 21;
    pub const CMD_FETCH_REG: u8 = 22;
    pub const FINISHED: u8 = 23;
    pub const CMD_TEST_AUPRC: u8 = 24;
    pub const CMD_FETCH_TELEMETRY: u8 = 25;
    // link-probe pair (v10)
    pub const PROBE: u8 = 26;
    pub const PROBED: u8 = 27;
    pub const REPLY_ACK: u8 = 30;
    pub const REPLY_GRAD: u8 = 31;
    pub const REPLY_PAIR: u8 = 32;
    pub const REPLY_SOLVE: u8 = 33;
    pub const REPLY_WARM: u8 = 34;
    pub const REPLY_VECTOR: u8 = 35;
    pub const REPLY_SCALAR: u8 = 36;
    pub const REPLY_DOTS: u8 = 37;
    pub const REPLY_TELEMETRY: u8 = 38;
    // serving plane (v7)
    pub const SCORE: u8 = 40;
    pub const SCORES: u8 = 41;
    pub const PUBLISH: u8 = 42;
    pub const PUBLISHED: u8 = 43;
    // LocalSolve payload sub-tags
    pub const SOLVE_ADMM_PROX: u8 = 1;
    pub const SOLVE_COCOA_SDCA: u8 = 2;
    pub const SOLVE_SSZ_PROX: u8 = 3;
    pub const SOLVE_FEATURE: u8 = 4;
    // DualUpdate payload sub-tags
    pub const DUAL_ADMM: u8 = 1;
    // VecRef sub-tags
    pub const REF_INLINE: u8 = 0;
    pub const REF_REG: u8 = 1;
    // VecOp sub-tags
    pub const OP_COPY: u8 = 1;
    pub const OP_ZERO: u8 = 2;
    pub const OP_SCALE: u8 = 3;
    pub const OP_AXPY: u8 = 4;
    pub const OP_AXPBY: u8 = 5;
    // Combine sub-tags
    pub const COMBINE_WEIGHTED_SUM: u8 = 1;
    pub const COMBINE_DIRECTION: u8 = 2;
    pub const COMBINE_COVERAGE: u8 = 3;
    pub const COMBINE_STEP: u8 = 4;
    pub const COMBINE_WEIGHTED_AVG: u8 = 5;
    pub const COMBINE_ADMM: u8 = 6;
}

fn enc_vecref(e: &mut Enc, r: &VecRef) {
    match r {
        VecRef::Inline(v) => {
            e.u8(tag::REF_INLINE);
            e.vec_f64(v);
        }
        VecRef::Reg(i) => {
            e.u8(tag::REF_REG);
            e.u32(*i);
        }
    }
}

fn dec_vecref(d: &mut Dec) -> Result<VecRef, String> {
    match d.u8()? {
        tag::REF_INLINE => Ok(VecRef::Inline(d.vec_f64()?)),
        tag::REF_REG => Ok(VecRef::Reg(d.u32()?)),
        other => Err(format!("unknown vector-ref tag {other}")),
    }
}

fn enc_vecop(e: &mut Enc, op: &VecOp) {
    match *op {
        VecOp::Copy { dst, src } => {
            e.u8(tag::OP_COPY);
            e.u32(dst);
            e.u32(src);
        }
        VecOp::Zero { dst } => {
            e.u8(tag::OP_ZERO);
            e.u32(dst);
        }
        VecOp::Scale { dst, a } => {
            e.u8(tag::OP_SCALE);
            e.u32(dst);
            e.f64(a);
        }
        VecOp::Axpy { dst, a, src } => {
            e.u8(tag::OP_AXPY);
            e.u32(dst);
            e.f64(a);
            e.u32(src);
        }
        VecOp::Axpby { dst, a, src, b } => {
            e.u8(tag::OP_AXPBY);
            e.u32(dst);
            e.f64(a);
            e.u32(src);
            e.f64(b);
        }
    }
}

fn dec_vecop(d: &mut Dec) -> Result<VecOp, String> {
    Ok(match d.u8()? {
        tag::OP_COPY => VecOp::Copy { dst: d.u32()?, src: d.u32()? },
        tag::OP_ZERO => VecOp::Zero { dst: d.u32()? },
        tag::OP_SCALE => VecOp::Scale { dst: d.u32()?, a: d.f64()? },
        tag::OP_AXPY => VecOp::Axpy { dst: d.u32()?, a: d.f64()?, src: d.u32()? },
        tag::OP_AXPBY => VecOp::Axpby {
            dst: d.u32()?,
            a: d.f64()?,
            src: d.u32()?,
            b: d.f64()?,
        },
        other => return Err(format!("unknown vec-op tag {other}")),
    })
}

fn enc_dots(e: &mut Enc, dots: &[(u32, u32)]) {
    e.u64(dots.len() as u64);
    for &(a, b) in dots {
        e.u32(a);
        e.u32(b);
    }
}

fn dec_dots(d: &mut Dec) -> Result<Vec<(u32, u32)>, String> {
    let len = d.u64()? as usize;
    if len.saturating_mul(8) > d.buf.len() - d.pos {
        return Err(format!("truncated dot list of claimed length {len}"));
    }
    let mut dots = Vec::with_capacity(len);
    for _ in 0..len {
        dots.push((d.u32()?, d.u32()?));
    }
    Ok(dots)
}

fn enc_combine(e: &mut Enc, spec: &CombineSpec) {
    e.vec_f64(&spec.weights);
    match &spec.kind {
        Combine::WeightedSum => e.u8(tag::COMBINE_WEIGHTED_SUM),
        Combine::Direction { anchor } => {
            e.u8(tag::COMBINE_DIRECTION);
            e.u32(*anchor);
        }
        Combine::CoverageDirection { anchor } => {
            e.u8(tag::COMBINE_COVERAGE);
            e.u32(*anchor);
        }
        Combine::Step { anchor, scale } => {
            e.u8(tag::COMBINE_STEP);
            e.u32(*anchor);
            e.f64(*scale);
        }
        Combine::WeightedAvg => e.u8(tag::COMBINE_WEIGHTED_AVG),
        Combine::AdmmConsensus { rho, lambda } => {
            e.u8(tag::COMBINE_ADMM);
            e.f64(*rho);
            e.f64(*lambda);
        }
    }
    match spec.store {
        Some(r) => {
            e.u8(1);
            e.u32(r);
        }
        None => e.u8(0),
    }
    enc_dots(e, &spec.dots);
}

fn dec_combine(d: &mut Dec) -> Result<CombineSpec, String> {
    let weights = d.vec_f64()?;
    let kind = match d.u8()? {
        tag::COMBINE_WEIGHTED_SUM => Combine::WeightedSum,
        tag::COMBINE_DIRECTION => Combine::Direction { anchor: d.u32()? },
        tag::COMBINE_COVERAGE => Combine::CoverageDirection { anchor: d.u32()? },
        tag::COMBINE_STEP => Combine::Step { anchor: d.u32()?, scale: d.f64()? },
        tag::COMBINE_WEIGHTED_AVG => Combine::WeightedAvg,
        tag::COMBINE_ADMM => Combine::AdmmConsensus { rho: d.f64()?, lambda: d.f64()? },
        other => return Err(format!("unknown combine tag {other}")),
    };
    let store = if d.u8()? == 1 { Some(d.u32()?) } else { None };
    let dots = dec_dots(d)?;
    Ok(CombineSpec { weights, kind, store, dots })
}

fn check_version(got: u32) -> Result<(), String> {
    if got != PROTO_VERSION {
        return Err(format!(
            "wire protocol version mismatch: peer speaks v{got}, this binary \
             speaks v{PROTO_VERSION} — rebuild all binaries from the same tree \
             (a stale `worker` executable is the usual cause)"
        ));
    }
    Ok(())
}

/// Serialize a message into a frame payload.
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut e = Enc::new();
    match msg {
        Msg::Setup(s) => {
            e.u8(tag::SETUP);
            e.u32(PROTO_VERSION);
            e.usize(s.rank);
            e.usize(s.p);
            e.str(&s.dataset);
            e.usize(s.quick_n);
            e.usize(s.quick_m);
            e.usize(s.quick_nnz);
            e.f64(s.scale);
            e.u64(s.seed);
            e.f64(s.test_fraction);
            e.str(&s.file_path);
            e.str(strategy_name(s.partition));
            e.str(s.data_plane.name());
            e.str(&s.p2p_bind);
            e.u32(u32::from(s.p2p_port_base));
            e.usize(s.threads);
            e.bool(s.telemetry);
            e.bool(s.simd);
            e.bool(s.overlap);
            e.str(s.frame_encoding.name());
            e.str(s.residency.name());
            e.usize(s.page_budget_mb);
            e.usize(s.prefetch_depth);
            e.str(s.topology.name());
            e.bool(s.topology_auto);
        }
        Msg::Shutdown => e.u8(tag::SHUTDOWN),
        Msg::Ready { m, n, nnz, data_port, now_ns } => {
            e.u8(tag::READY);
            e.u32(PROTO_VERSION);
            e.usize(*m);
            e.usize(*n);
            e.usize(*nnz);
            e.u32(u32::from(*data_port));
            e.u64(*now_ns);
        }
        Msg::Abort { msg } => {
            e.u8(tag::ABORT);
            e.str(msg);
        }
        Msg::Mesh { addrs } => {
            e.u8(tag::MESH);
            e.u64(addrs.len() as u64);
            for addr in addrs {
                e.str(addr);
            }
        }
        Msg::MeshOk => e.u8(tag::MESH_OK),
        Msg::Probe { rounds, small_m, large_m } => {
            e.u8(tag::PROBE);
            e.u32(*rounds);
            e.usize(*small_m);
            e.usize(*large_m);
        }
        Msg::Probed { small_ns, large_ns } => {
            e.u8(tag::PROBED);
            e.u64(*small_ns);
            e.u64(*large_ns);
        }
        Msg::Reduce { cmd, topology, spec } => {
            e.u8(tag::REDUCE);
            e.str(topology.name());
            enc_combine(&mut e, spec);
            enc_cmd(&mut e, cmd);
        }
        Msg::Reduced {
            reply,
            data_tx,
            data_rx,
            secs,
            compute_secs,
            queue_ns,
            stall_ns,
            overlap_ns,
            page_ns,
            dots,
        } => {
            e.u8(tag::REDUCED);
            e.u64(*data_tx);
            e.u64(*data_rx);
            e.f64(*secs);
            e.f64(*compute_secs);
            e.u64(*queue_ns);
            e.u64(*stall_ns);
            e.u64(*overlap_ns);
            e.u64(*page_ns);
            e.vec_f64(dots);
            enc_reply(&mut e, reply);
        }
        Msg::Finish { sums } => {
            e.u8(tag::FINISH);
            e.u64(sums.len() as u64);
            for s in sums {
                e.vec_f64(s);
            }
        }
        Msg::Finished { dots } => {
            e.u8(tag::FINISHED);
            e.vec_f64(dots);
        }
        Msg::Cmd(cmd) => enc_cmd(&mut e, cmd),
        Msg::Reply { reply, secs, queue_ns, page_ns } => {
            enc_reply(&mut e, reply);
            e.f64(*secs);
            e.u64(*queue_ns);
            e.u64(*page_ns);
        }
        Msg::Score { id, cols, row_nnz, col_idx, values } => {
            e.u8(tag::SCORE);
            e.u32(PROTO_VERSION);
            e.u64(*id);
            e.usize(*cols);
            e.vec_u32(row_nnz);
            e.vec_u32(col_idx);
            e.vec_f32(values);
        }
        Msg::Scores { id, epoch, margins } => {
            e.u8(tag::SCORES);
            e.u64(*id);
            e.u64(*epoch);
            e.vec_f64(margins);
        }
        Msg::Publish { loss, lambda, weights } => {
            e.u8(tag::PUBLISH);
            e.u32(PROTO_VERSION);
            e.str(loss.name());
            e.f64(*lambda);
            e.vec_f64(weights);
        }
        Msg::Published { epoch } => {
            e.u8(tag::PUBLISHED);
            e.u64(*epoch);
        }
    }
    e.buf
}

/// Append a command (with its `CMD_*` tag) — shared by `Cmd` and the
/// fused `Reduce` encoding.
fn enc_cmd(e: &mut Enc, cmd: &Command) {
    match cmd {
        Command::Reset => e.u8(tag::CMD_RESET),
        Command::Grad { loss, w } => {
            e.u8(tag::CMD_GRAD);
            e.str(loss.name());
            enc_vecref(e, w);
        }
        Command::Dirs { d } => {
            e.u8(tag::CMD_DIRS);
            enc_vecref(e, d);
        }
        Command::Linesearch { loss, t } => {
            e.u8(tag::CMD_LINESEARCH);
            e.str(loss.name());
            e.f64(*t);
        }
        Command::InnerSolve(spec) => {
            e.u8(tag::CMD_INNER_SOLVE);
            e.str(spec.kind.name());
            e.str(&spec.inner);
            e.usize(spec.k_hat);
            e.opt_f64(spec.trust_radius);
            e.f64(spec.lambda);
            e.str(spec.loss.name());
            enc_vecref(e, &spec.anchor);
            enc_vecref(e, &spec.full_grad);
            match &spec.data_grad {
                Some(r) => {
                    e.u8(1);
                    enc_vecref(e, r);
                }
                None => e.u8(0),
            }
        }
        Command::Warmstart { loss, lambda, epochs, seed } => {
            e.u8(tag::CMD_WARMSTART);
            e.str(loss.name());
            e.f64(*lambda);
            e.u32(*epochs);
            e.u64(*seed);
        }
        Command::Hvp { loss, s } => {
            e.u8(tag::CMD_HVP);
            e.str(loss.name());
            enc_vecref(e, s);
        }
        Command::LossEval { loss, w } => {
            e.u8(tag::CMD_LOSS_EVAL);
            e.str(loss.name());
            enc_vecref(e, w);
        }
        Command::LocalSolve(spec) => {
            e.u8(tag::CMD_LOCAL_SOLVE);
            match spec {
                LocalSolveSpec::AdmmProx { loss, rho, local_iters, init, u_scale, z } => {
                    e.u8(tag::SOLVE_ADMM_PROX);
                    e.str(loss.name());
                    e.f64(*rho);
                    e.u32(*local_iters);
                    e.bool(*init);
                    e.f64(*u_scale);
                    enc_vecref(e, z);
                }
                LocalSolveSpec::CocoaSdca { lambda, epochs, seed, round, w } => {
                    e.u8(tag::SOLVE_COCOA_SDCA);
                    e.f64(*lambda);
                    e.f64(*epochs);
                    e.u64(*seed);
                    e.u64(*round);
                    enc_vecref(e, w);
                }
                LocalSolveSpec::SszProx {
                    loss,
                    lambda,
                    mu,
                    local_iters,
                    anchor,
                    full_grad,
                    grad_shift,
                } => {
                    e.u8(tag::SOLVE_SSZ_PROX);
                    e.str(loss.name());
                    e.f64(*lambda);
                    e.f64(*mu);
                    e.u32(*local_iters);
                    enc_vecref(e, anchor);
                    enc_vecref(e, full_grad);
                    enc_vecref(e, grad_shift);
                }
                LocalSolveSpec::FeatureSolve {
                    loss,
                    lambda,
                    k_hat,
                    anchor,
                    full_grad,
                    subsets,
                } => {
                    e.u8(tag::SOLVE_FEATURE);
                    e.str(loss.name());
                    e.f64(*lambda);
                    e.u32(*k_hat);
                    enc_vecref(e, anchor);
                    enc_vecref(e, full_grad);
                    e.vec_vec_u32(subsets);
                }
            }
        }
        Command::DualUpdate(spec) => {
            e.u8(tag::CMD_DUAL_UPDATE);
            match spec {
                DualUpdateSpec::AdmmDual => e.u8(tag::DUAL_ADMM),
            }
        }
        Command::VecOps { ops, dots } => {
            e.u8(tag::CMD_VEC_OPS);
            e.u64(ops.len() as u64);
            for op in ops {
                enc_vecop(e, op);
            }
            enc_dots(e, dots);
        }
        Command::SetReg { reg, v } => {
            e.u8(tag::CMD_SET_REG);
            e.u32(*reg);
            e.vec_f64(v);
        }
        Command::FetchReg { reg } => {
            e.u8(tag::CMD_FETCH_REG);
            e.u32(*reg);
        }
        Command::TestAuprc { w } => {
            e.u8(tag::CMD_TEST_AUPRC);
            enc_vecref(e, w);
        }
        Command::FetchTelemetry => e.u8(tag::CMD_FETCH_TELEMETRY),
    }
}

/// Append a reply (with its `REPLY_*` tag) — shared by `Reply` and the
/// fused `Reduced` encoding.
fn enc_reply(e: &mut Enc, reply: &Reply) {
    match reply {
        Reply::Ack { units } => {
            e.u8(tag::REPLY_ACK);
            e.f64(*units);
        }
        Reply::Grad { loss, grad, units } => {
            e.u8(tag::REPLY_GRAD);
            e.f64(*loss);
            e.vec_f64(grad);
            e.f64(*units);
        }
        Reply::Pair { a, b, units } => {
            e.u8(tag::REPLY_PAIR);
            e.f64(*a);
            e.f64(*b);
            e.f64(*units);
        }
        Reply::Solve { w, n, units } => {
            e.u8(tag::REPLY_SOLVE);
            e.vec_f64(w);
            e.usize(*n);
            e.f64(*units);
        }
        Reply::Warm { w, counts, units } => {
            e.u8(tag::REPLY_WARM);
            e.vec_f64(w);
            e.vec_f64(counts);
            e.f64(*units);
        }
        Reply::Vector { v, units } => {
            e.u8(tag::REPLY_VECTOR);
            e.vec_f64(v);
            e.f64(*units);
        }
        Reply::Scalar { v, units } => {
            e.u8(tag::REPLY_SCALAR);
            e.f64(*v);
            e.f64(*units);
        }
        Reply::Dots { vals, units } => {
            e.u8(tag::REPLY_DOTS);
            e.vec_f64(vals);
            e.f64(*units);
        }
        Reply::Telemetry { spans, dropped, units } => {
            e.u8(tag::REPLY_TELEMETRY);
            e.u64(spans.len() as u64);
            for s in spans {
                e.str(&s.name);
                e.u32(s.rank);
                e.u32(s.thread);
                e.u64(s.t_start_ns);
                e.u64(s.t_end_ns);
                e.u64(s.bytes);
            }
            e.u64(*dropped);
            e.f64(*units);
        }
    }
}

/// Deserialize a frame payload.
pub fn decode(payload: &[u8]) -> Result<Msg, String> {
    let mut d = Dec::new(payload);
    let t = d.u8()?;
    let msg = match t {
        tag::SETUP => Msg::Setup(WorkerSetup {
            rank: {
                check_version(d.u32()?)?;
                d.usize()?
            },
            p: d.usize()?,
            dataset: d.str()?,
            quick_n: d.usize()?,
            quick_m: d.usize()?,
            quick_nnz: d.usize()?,
            scale: d.f64()?,
            seed: d.u64()?,
            test_fraction: d.f64()?,
            file_path: d.str()?,
            partition: strategy_from(&d.str()?)?,
            data_plane: {
                let name = d.str()?;
                DataPlane::from_name(&name)
                    .ok_or_else(|| format!("unknown data plane {name:?}"))?
            },
            p2p_bind: d.str()?,
            p2p_port_base: port_from(d.u32()?)?,
            threads: d.usize()?,
            telemetry: d.bool()?,
            simd: d.bool()?,
            overlap: d.bool()?,
            frame_encoding: {
                let name = d.str()?;
                FrameEncoding::from_name(&name)
                    .ok_or_else(|| format!("unknown frame encoding {name:?}"))?
            },
            residency: {
                let name = d.str()?;
                Residency::from_name(&name)
                    .ok_or_else(|| format!("unknown residency {name:?}"))?
            },
            page_budget_mb: d.usize()?,
            prefetch_depth: d.usize()?,
            topology: {
                let name = d.str()?;
                Topology::from_name(&name)
                    .ok_or_else(|| format!("unknown topology {name:?}"))?
            },
            topology_auto: d.bool()?,
        }),
        tag::SHUTDOWN => Msg::Shutdown,
        tag::READY => Msg::Ready {
            m: {
                check_version(d.u32()?)?;
                d.usize()?
            },
            n: d.usize()?,
            nnz: d.usize()?,
            data_port: port_from(d.u32()?)?,
            now_ns: d.u64()?,
        },
        tag::ABORT => Msg::Abort { msg: d.str()? },
        tag::MESH => {
            let len = d.u64()? as usize;
            // each address costs at least its 4-byte length prefix
            if len.saturating_mul(4) > payload.len() {
                return Err(format!("truncated mesh list of claimed length {len}"));
            }
            let mut addrs = Vec::with_capacity(len);
            for _ in 0..len {
                addrs.push(d.str()?);
            }
            Msg::Mesh { addrs }
        }
        tag::MESH_OK => Msg::MeshOk,
        tag::PROBE => Msg::Probe {
            rounds: d.u32()?,
            small_m: d.usize()?,
            large_m: d.usize()?,
        },
        tag::PROBED => Msg::Probed {
            small_ns: d.u64()?,
            large_ns: d.u64()?,
        },
        tag::REDUCE => {
            let topo_name = d.str()?;
            let topology = Topology::from_name(&topo_name)
                .ok_or_else(|| format!("unknown topology {topo_name:?}"))?;
            let spec = dec_combine(&mut d)?;
            let ct = d.u8()?;
            Msg::Reduce { cmd: dec_cmd(&mut d, ct)?, topology, spec }
        }
        tag::REDUCED => {
            let data_tx = d.u64()?;
            let data_rx = d.u64()?;
            let secs = d.f64()?;
            let compute_secs = d.f64()?;
            let queue_ns = d.u64()?;
            let stall_ns = d.u64()?;
            let overlap_ns = d.u64()?;
            let page_ns = d.u64()?;
            let dots = d.vec_f64()?;
            let rt = d.u8()?;
            Msg::Reduced {
                reply: dec_reply(&mut d, rt)?,
                data_tx,
                data_rx,
                secs,
                compute_secs,
                queue_ns,
                stall_ns,
                overlap_ns,
                page_ns,
                dots,
            }
        }
        tag::FINISH => {
            let len = d.u64()? as usize;
            // each sum costs at least its 8-byte length prefix
            if len.saturating_mul(8) > payload.len() {
                return Err(format!("truncated finish list of claimed length {len}"));
            }
            let mut sums = Vec::with_capacity(len);
            for _ in 0..len {
                sums.push(d.vec_f64()?);
            }
            Msg::Finish { sums }
        }
        tag::FINISHED => Msg::Finished { dots: d.vec_f64()? },
        t @ (tag::CMD_RESET..=tag::CMD_FETCH_REG
        | tag::CMD_TEST_AUPRC
        | tag::CMD_FETCH_TELEMETRY) => Msg::Cmd(dec_cmd(&mut d, t)?),
        t @ tag::REPLY_ACK..=tag::REPLY_TELEMETRY => {
            let reply = dec_reply(&mut d, t)?;
            let secs = d.f64()?;
            let queue_ns = d.u64()?;
            let page_ns = d.u64()?;
            Msg::Reply { reply, secs, queue_ns, page_ns }
        }
        tag::SCORE => Msg::Score {
            id: {
                check_version(d.u32()?)?;
                d.u64()?
            },
            cols: d.usize()?,
            row_nnz: d.vec_u32()?,
            col_idx: d.vec_u32()?,
            values: d.vec_f32()?,
        },
        tag::SCORES => Msg::Scores {
            id: d.u64()?,
            epoch: d.u64()?,
            margins: d.vec_f64()?,
        },
        tag::PUBLISH => Msg::Publish {
            loss: {
                check_version(d.u32()?)?;
                loss_from(&d.str()?)?
            },
            lambda: d.f64()?,
            weights: d.vec_f64()?,
        },
        tag::PUBLISHED => Msg::Published { epoch: d.u64()? },
        other => return Err(format!("unknown message tag {other}")),
    };
    d.finish()?;
    Ok(msg)
}

/// Decode a command whose `CMD_*` tag byte has already been read —
/// shared by `Cmd` and the fused `Reduce` decoding.
fn dec_cmd(d: &mut Dec, t: u8) -> Result<Command, String> {
    Ok(match t {
        tag::CMD_RESET => Command::Reset,
        tag::CMD_GRAD => Command::Grad {
            loss: loss_from(&d.str()?)?,
            w: dec_vecref(d)?,
        },
        tag::CMD_DIRS => Command::Dirs { d: dec_vecref(d)? },
        tag::CMD_LINESEARCH => Command::Linesearch {
            loss: loss_from(&d.str()?)?,
            t: d.f64()?,
        },
        tag::CMD_INNER_SOLVE => Command::InnerSolve(InnerSolveSpec {
            kind: approx_from(&d.str()?)?,
            inner: d.str()?,
            k_hat: d.usize()?,
            trust_radius: d.opt_f64()?,
            lambda: d.f64()?,
            loss: loss_from(&d.str()?)?,
            anchor: dec_vecref(d)?,
            full_grad: dec_vecref(d)?,
            data_grad: if d.u8()? == 1 { Some(dec_vecref(d)?) } else { None },
        }),
        tag::CMD_WARMSTART => Command::Warmstart {
            loss: loss_from(&d.str()?)?,
            lambda: d.f64()?,
            epochs: d.u32()?,
            seed: d.u64()?,
        },
        tag::CMD_HVP => Command::Hvp {
            loss: loss_from(&d.str()?)?,
            s: dec_vecref(d)?,
        },
        tag::CMD_LOSS_EVAL => Command::LossEval {
            loss: loss_from(&d.str()?)?,
            w: dec_vecref(d)?,
        },
        tag::CMD_LOCAL_SOLVE => {
            let sub = d.u8()?;
            let spec = match sub {
                tag::SOLVE_ADMM_PROX => LocalSolveSpec::AdmmProx {
                    loss: loss_from(&d.str()?)?,
                    rho: d.f64()?,
                    local_iters: d.u32()?,
                    init: d.bool()?,
                    u_scale: d.f64()?,
                    z: dec_vecref(d)?,
                },
                tag::SOLVE_COCOA_SDCA => LocalSolveSpec::CocoaSdca {
                    lambda: d.f64()?,
                    epochs: d.f64()?,
                    seed: d.u64()?,
                    round: d.u64()?,
                    w: dec_vecref(d)?,
                },
                tag::SOLVE_SSZ_PROX => LocalSolveSpec::SszProx {
                    loss: loss_from(&d.str()?)?,
                    lambda: d.f64()?,
                    mu: d.f64()?,
                    local_iters: d.u32()?,
                    anchor: dec_vecref(d)?,
                    full_grad: dec_vecref(d)?,
                    grad_shift: dec_vecref(d)?,
                },
                tag::SOLVE_FEATURE => LocalSolveSpec::FeatureSolve {
                    loss: loss_from(&d.str()?)?,
                    lambda: d.f64()?,
                    k_hat: d.u32()?,
                    anchor: dec_vecref(d)?,
                    full_grad: dec_vecref(d)?,
                    subsets: d.vec_vec_u32()?,
                },
                other => return Err(format!("unknown local-solve payload tag {other}")),
            };
            Command::LocalSolve(spec)
        }
        tag::CMD_DUAL_UPDATE => {
            let sub = d.u8()?;
            let spec = match sub {
                tag::DUAL_ADMM => DualUpdateSpec::AdmmDual,
                other => return Err(format!("unknown dual-update payload tag {other}")),
            };
            Command::DualUpdate(spec)
        }
        tag::CMD_VEC_OPS => {
            let len = d.u64()? as usize;
            // each op costs at least its tag + one u32 operand
            if len.saturating_mul(5) > d.buf.len() - d.pos {
                return Err(format!("truncated op list of claimed length {len}"));
            }
            let mut ops = Vec::with_capacity(len);
            for _ in 0..len {
                ops.push(dec_vecop(d)?);
            }
            Command::VecOps { ops, dots: dec_dots(d)? }
        }
        tag::CMD_SET_REG => Command::SetReg { reg: d.u32()?, v: d.vec_f64()? },
        tag::CMD_FETCH_REG => Command::FetchReg { reg: d.u32()? },
        tag::CMD_TEST_AUPRC => Command::TestAuprc { w: dec_vecref(d)? },
        tag::CMD_FETCH_TELEMETRY => Command::FetchTelemetry,
        other => return Err(format!("unknown command tag {other}")),
    })
}

/// Decode a reply whose `REPLY_*` tag byte has already been read —
/// shared by `Reply` and the fused `Reduced` decoding.
fn dec_reply(d: &mut Dec, t: u8) -> Result<Reply, String> {
    Ok(match t {
        tag::REPLY_ACK => Reply::Ack { units: d.f64()? },
        tag::REPLY_GRAD => Reply::Grad {
            loss: d.f64()?,
            grad: d.vec_f64()?,
            units: d.f64()?,
        },
        tag::REPLY_PAIR => Reply::Pair {
            a: d.f64()?,
            b: d.f64()?,
            units: d.f64()?,
        },
        tag::REPLY_SOLVE => Reply::Solve {
            w: d.vec_f64()?,
            n: d.usize()?,
            units: d.f64()?,
        },
        tag::REPLY_WARM => Reply::Warm {
            w: d.vec_f64()?,
            counts: d.vec_f64()?,
            units: d.f64()?,
        },
        tag::REPLY_VECTOR => Reply::Vector {
            v: d.vec_f64()?,
            units: d.f64()?,
        },
        tag::REPLY_SCALAR => Reply::Scalar {
            v: d.f64()?,
            units: d.f64()?,
        },
        tag::REPLY_DOTS => Reply::Dots {
            vals: d.vec_f64()?,
            units: d.f64()?,
        },
        tag::REPLY_TELEMETRY => {
            let len = d.u64()? as usize;
            // each span costs at least its name length prefix + fixed fields
            if len.saturating_mul(36) > d.buf.len() - d.pos {
                return Err(format!("truncated span list of claimed length {len}"));
            }
            let mut spans = Vec::with_capacity(len);
            for _ in 0..len {
                spans.push(TelemetrySpan {
                    name: std::borrow::Cow::Owned(d.str()?),
                    rank: d.u32()?,
                    thread: d.u32()?,
                    t_start_ns: d.u64()?,
                    t_end_ns: d.u64()?,
                    bytes: d.u64()?,
                });
            }
            Reply::Telemetry {
                spans,
                dropped: d.u64()?,
                units: d.f64()?,
            }
        }
        other => return Err(format!("unknown reply tag {other}")),
    })
}

// ---------------------------------------------------------------------------
// Driver data-payload accounting
// ---------------------------------------------------------------------------

fn vecref_bytes(r: &VecRef) -> u64 {
    match r {
        VecRef::Inline(v) => 8 * v.len() as u64,
        VecRef::Reg(_) => 0,
    }
}

/// f64 data-vector payload bytes a command carries (inline `VecRef`s
/// and explicit vector payloads). Scalar aggregates — dot-request
/// lists, op coefficients, per-rank weights — are control traffic and
/// excluded; so are the `u32` feature subsets (static partition
/// metadata, shipped once).
pub fn cmd_data_bytes(cmd: &Command) -> u64 {
    match cmd {
        Command::Reset
        | Command::Linesearch { .. }
        | Command::Warmstart { .. }
        | Command::VecOps { .. }
        | Command::FetchReg { .. }
        | Command::FetchTelemetry => 0,
        Command::Grad { w, .. }
        | Command::LossEval { w, .. }
        | Command::TestAuprc { w } => vecref_bytes(w),
        Command::Dirs { d } => vecref_bytes(d),
        Command::Hvp { s, .. } => vecref_bytes(s),
        Command::InnerSolve(spec) => {
            vecref_bytes(&spec.anchor)
                + vecref_bytes(&spec.full_grad)
                + spec.data_grad.as_ref().map(vecref_bytes).unwrap_or(0)
        }
        Command::LocalSolve(spec) => match spec {
            LocalSolveSpec::AdmmProx { z, .. } => vecref_bytes(z),
            LocalSolveSpec::CocoaSdca { w, .. } => vecref_bytes(w),
            LocalSolveSpec::SszProx { anchor, full_grad, grad_shift, .. } => {
                vecref_bytes(anchor) + vecref_bytes(full_grad) + vecref_bytes(grad_shift)
            }
            LocalSolveSpec::FeatureSolve { anchor, full_grad, .. } => {
                vecref_bytes(anchor) + vecref_bytes(full_grad)
            }
        },
        Command::DualUpdate(DualUpdateSpec::AdmmDual) => 0,
        Command::SetReg { v, .. } => 8 * v.len() as u64,
    }
}

/// f64 data-vector payload bytes a reply carries. The `Dots` reply is
/// a scalar aggregate (replicated dot products) — control traffic,
/// and so is the `Telemetry` span flush (instrumentation, not model
/// data — the scalar-driver invariant is unaffected by telemetry).
pub fn reply_data_bytes(reply: &Reply) -> u64 {
    match reply {
        Reply::Ack { .. } | Reply::Pair { .. } | Reply::Scalar { .. } => 0,
        Reply::Dots { .. } | Reply::Telemetry { .. } => 0,
        Reply::Grad { grad, .. } => 8 * grad.len() as u64,
        Reply::Solve { w, .. } => 8 * w.len() as u64,
        Reply::Warm { w, counts, .. } => 8 * (w.len() + counts.len()) as u64,
        Reply::Vector { v, .. } => 8 * v.len() as u64,
    }
}

/// f64 data-vector payload bytes a message moves over a driver link —
/// the [`super::Measured::driver_data_bytes`] accounting. Under the p2p
/// data plane this must be 0 for every frame after round 0: the
/// scalar-only driver invariant. The v7 serving frames ride serving
/// connections, never a training driver link, but are accounted the
/// same way (data vectors count, ids/epochs are control scalars) so a
/// serving-plane byte budget composes with the training one.
pub fn msg_data_bytes(msg: &Msg) -> u64 {
    match msg {
        Msg::Setup(_)
        | Msg::Shutdown
        | Msg::Ready { .. }
        | Msg::Abort { .. }
        | Msg::Mesh { .. }
        | Msg::MeshOk
        | Msg::Probe { .. }
        | Msg::Probed { .. }
        | Msg::Finished { .. }
        | Msg::Published { .. } => 0,
        Msg::Cmd(cmd) | Msg::Reduce { cmd, .. } => cmd_data_bytes(cmd),
        Msg::Reply { reply, .. } => reply_data_bytes(reply),
        Msg::Reduced { reply, .. } => reply_data_bytes(reply),
        Msg::Finish { sums } => {
            sums.iter().map(|s| 8 * s.len() as u64).sum()
        }
        Msg::Score { values, .. } => 4 * values.len() as u64,
        Msg::Scores { margins, .. } => 8 * margins.len() as u64,
        Msg::Publish { weights, .. } => 8 * weights.len() as u64,
    }
}

/// Convenience: encode + frame in one call, returning bytes written.
pub fn send(w: &mut impl Write, msg: &Msg) -> Result<u64, String> {
    write_frame(w, &encode(msg))
}

/// Convenience: read + decode one message. `Ok(None)` on clean EOF.
pub fn recv(r: &mut impl Read) -> Result<Option<Msg>, String> {
    match read_frame(r)? {
        Some(payload) => Ok(Some(decode(&payload)?)),
        None => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::ApproxKind;
    use crate::data::partition::Strategy;
    use crate::loss::Loss;
    use crate::net::{Command, InnerSolveSpec, Reply, VecOp, VecRef, WorkerSetup};

    fn roundtrip(msg: Msg) {
        let bytes = encode(&msg);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn every_variant_roundtrips() {
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Ready {
            m: 10,
            n: 99,
            nnz: 1234,
            data_port: 40551,
            now_ns: 987_654_321,
        });
        roundtrip(Msg::Abort { msg: "boom ü".into() });
        roundtrip(Msg::Setup(WorkerSetup {
            rank: 3,
            p: 8,
            dataset: "quick".into(),
            quick_n: 500,
            quick_m: 40,
            quick_nnz: 8,
            scale: 1e-3,
            seed: 42,
            test_fraction: 0.2,
            file_path: String::new(),
            partition: Strategy::RoundRobin,
            data_plane: crate::net::DataPlane::P2p,
            p2p_bind: "127.0.0.1,10.0.0.2".into(),
            p2p_port_base: 9100,
            threads: 4,
            telemetry: true,
            simd: false,
            overlap: true,
            frame_encoding: FrameEncoding::F32,
            residency: Residency::Paged,
            page_budget_mb: 48,
            prefetch_depth: 3,
            topology: Topology::HalvingDoubling,
            topology_auto: true,
        }));
        roundtrip(Msg::Probe { rounds: 5, small_m: 16, large_m: 65_536 });
        roundtrip(Msg::Probed { small_ns: 12_345, large_ns: 9_876_543 });
        roundtrip(Msg::Cmd(Command::Reset));
        roundtrip(Msg::Cmd(Command::Grad {
            loss: Loss::Logistic,
            w: VecRef::Inline(vec![1.0, -2.5, f64::MIN_POSITIVE, 0.1 + 0.2]),
        }));
        roundtrip(Msg::Cmd(Command::Grad { loss: Loss::Logistic, w: VecRef::Reg(3) }));
        roundtrip(Msg::Cmd(Command::Dirs { d: VecRef::Inline(vec![]) }));
        roundtrip(Msg::Cmd(Command::Dirs { d: VecRef::Reg(0) }));
        roundtrip(Msg::Cmd(Command::Linesearch {
            loss: Loss::SquaredHinge,
            t: 0.625,
        }));
        roundtrip(Msg::Cmd(Command::InnerSolve(InnerSolveSpec {
            kind: ApproxKind::Bfgs,
            inner: "tron".into(),
            k_hat: 10,
            trust_radius: Some(0.75),
            lambda: 1e-4,
            loss: Loss::SquaredHinge,
            anchor: VecRef::Inline(vec![0.1, 0.2]),
            full_grad: VecRef::Reg(2),
            data_grad: Some(VecRef::Inline(vec![7.0])),
        })));
        roundtrip(Msg::Cmd(Command::Warmstart {
            loss: Loss::LeastSquares,
            lambda: 0.5,
            epochs: 5,
            seed: 7,
        }));
        let reply =
            |reply: Reply, secs: f64| Msg::Reply { reply, secs, queue_ns: 512, page_ns: 64 };
        roundtrip(reply(Reply::Ack { units: 12.0 }, 0.5));
        roundtrip(reply(
            Reply::Grad { loss: 3.5, grad: vec![1.0; 7], units: 2.0 },
            0.015625,
        ));
        roundtrip(reply(Reply::Pair { a: 1.0, b: -2.0, units: 3.0 }, 0.0));
        roundtrip(reply(
            Reply::Solve { w: vec![9.0, 8.0], n: 55, units: 4.0 },
            1.5,
        ));
        roundtrip(reply(
            Reply::Warm { w: vec![0.5], counts: vec![3.0], units: 5.0 },
            0.25,
        ));
        roundtrip(reply(Reply::Vector { v: vec![1.5, -2.5], units: 6.0 }, 0.0));
        roundtrip(reply(Reply::Scalar { v: 0.25, units: 0.0 }, 0.0));
        roundtrip(reply(Reply::Dots { vals: vec![0.5, -1.5], units: 0.0 }, 0.0));
        roundtrip(Msg::Cmd(Command::FetchTelemetry));
        // empty flush, a populated ring, and a full-ring flush with drops
        roundtrip(reply(
            Reply::Telemetry { spans: vec![], dropped: 0, units: 0.0 },
            0.0,
        ));
        let span = |name: &str, t: u64| crate::metrics::telemetry::Span {
            name: std::borrow::Cow::Owned(name.to_string()),
            rank: 3,
            thread: t as u32,
            t_start_ns: t,
            t_end_ns: t + 17,
            bytes: t * 8,
        };
        roundtrip(reply(
            Reply::Telemetry {
                spans: vec![span("cmd:grad", 1), span("mesh:recv \"x\"\n", 2)],
                dropped: 0,
                units: 0.0,
            },
            0.0,
        ));
        roundtrip(reply(
            Reply::Telemetry {
                spans: (0..64).map(|i| span("k", i)).collect(),
                dropped: 4096,
                units: 0.0,
            },
            0.0,
        ));
    }

    #[test]
    fn full_vocabulary_variants_roundtrip() {
        use crate::net::{DualUpdateSpec, LocalSolveSpec, VecOp};
        roundtrip(Msg::Cmd(Command::Hvp {
            loss: Loss::SquaredHinge,
            s: VecRef::Inline(vec![0.1, -0.2, 0.3]),
        }));
        roundtrip(Msg::Cmd(Command::Hvp { loss: Loss::SquaredHinge, s: VecRef::Reg(5) }));
        roundtrip(Msg::Cmd(Command::LossEval {
            loss: Loss::Logistic,
            w: VecRef::Inline(vec![]),
        }));
        roundtrip(Msg::Cmd(Command::LocalSolve(LocalSolveSpec::AdmmProx {
            loss: Loss::SquaredHinge,
            rho: 0.75,
            local_iters: 8,
            init: true,
            u_scale: 0.5,
            z: VecRef::Inline(vec![1.0, 2.0, 3.0]),
        })));
        roundtrip(Msg::Cmd(Command::LocalSolve(LocalSolveSpec::CocoaSdca {
            lambda: 1e-3,
            epochs: 0.1,
            seed: 0xc0c0,
            round: 7,
            w: VecRef::Reg(0),
        })));
        roundtrip(Msg::Cmd(Command::LocalSolve(LocalSolveSpec::SszProx {
            loss: Loss::SquaredHinge,
            lambda: 1e-2,
            mu: 3e-2,
            local_iters: 10,
            anchor: VecRef::Inline(vec![0.1]),
            full_grad: VecRef::Reg(2),
            grad_shift: VecRef::Inline(vec![]),
        })));
        roundtrip(Msg::Cmd(Command::LocalSolve(LocalSolveSpec::FeatureSolve {
            loss: Loss::SquaredHinge,
            lambda: 1e-2,
            k_hat: 10,
            anchor: VecRef::Inline(vec![0.0; 3]),
            full_grad: VecRef::Inline(vec![1.0; 3]),
            subsets: vec![vec![0, 2], vec![], vec![1]],
        })));
        roundtrip(Msg::Cmd(Command::DualUpdate(DualUpdateSpec::AdmmDual)));
        roundtrip(Msg::Cmd(Command::VecOps {
            ops: vec![
                VecOp::Copy { dst: 1, src: 0 },
                VecOp::Zero { dst: 2 },
                VecOp::Scale { dst: 1, a: -0.5 },
                VecOp::Axpy { dst: 1, a: 0.25, src: 2 },
                VecOp::Axpby { dst: 2, a: 1.0, src: 1, b: 0.75 },
            ],
            dots: vec![(0, 1), (2, 2)],
        }));
        roundtrip(Msg::Cmd(Command::VecOps { ops: vec![], dots: vec![] }));
        roundtrip(Msg::Cmd(Command::SetReg { reg: 9, v: vec![0.1 + 0.2, -0.0] }));
        roundtrip(Msg::Cmd(Command::FetchReg { reg: 63 }));
        roundtrip(Msg::Cmd(Command::TestAuprc { w: VecRef::Reg(0) }));
        roundtrip(Msg::Cmd(Command::TestAuprc {
            w: VecRef::Inline(vec![0.1 + 0.2, -0.0]),
        }));
    }

    #[test]
    fn data_plane_variants_roundtrip() {
        use crate::net::{Combine, CombineSpec};
        roundtrip(Msg::Mesh { addrs: vec![] });
        roundtrip(Msg::Mesh {
            addrs: vec!["127.0.0.1:9100".into(), "10.0.0.2:9101".into()],
        });
        roundtrip(Msg::MeshOk);
        let kinds = [
            Combine::WeightedSum,
            Combine::Direction { anchor: 0 },
            Combine::CoverageDirection { anchor: 7 },
            Combine::Step { anchor: 1, scale: 0.25 },
            Combine::WeightedAvg,
            Combine::AdmmConsensus { rho: 0.5, lambda: 1e-3 },
        ];
        for (topology, kind) in crate::net::Topology::all().iter().cycle().zip(kinds) {
            roundtrip(Msg::Reduce {
                cmd: Command::Grad {
                    loss: Loss::SquaredHinge,
                    w: VecRef::Inline(vec![0.1 + 0.2, -0.0, f64::MIN_POSITIVE]),
                },
                topology: *topology,
                spec: CombineSpec {
                    weights: vec![0.5, 0.25, 0.0, 1.0],
                    kind,
                    store: Some(4),
                    dots: vec![(4, 4), (0, 4)],
                },
            });
        }
        roundtrip(Msg::Reduce {
            cmd: Command::Hvp { loss: Loss::Logistic, s: VecRef::Reg(2) },
            topology: crate::net::Topology::Ring,
            spec: CombineSpec::sum_into(3),
        });
        roundtrip(Msg::Reduced {
            reply: Reply::Grad { loss: 2.5, grad: vec![1.0, -2.0], units: 7.0 },
            data_tx: 1234,
            data_rx: 4321,
            secs: 0.015625,
            compute_secs: 0.0078125,
            queue_ns: 2048,
            stall_ns: 1024,
            overlap_ns: 4096,
            page_ns: 8192,
            dots: vec![0.5, -0.25],
        });
        roundtrip(Msg::Reduced {
            reply: Reply::Vector { v: vec![], units: 0.0 },
            data_tx: 0,
            data_rx: 0,
            secs: 0.0,
            compute_secs: 0.0,
            queue_ns: 0,
            stall_ns: 0,
            overlap_ns: 0,
            page_ns: 0,
            dots: vec![],
        });
        roundtrip(Msg::Finish { sums: vec![] });
        roundtrip(Msg::Finish {
            sums: vec![vec![0.1 + 0.2, -0.0], vec![1.0, 2.0]],
        });
        roundtrip(Msg::Finished { dots: vec![] });
        roundtrip(Msg::Finished { dots: vec![9.5] });
        // an unknown topology name inside Reduce is rejected
        let mut e = Enc::new();
        e.u8(tag::REDUCE);
        e.str("mesh");
        e.u8(tag::CMD_RESET);
        assert!(decode(&e.buf).unwrap_err().contains("unknown topology"));
    }

    #[test]
    fn serving_frames_roundtrip() {
        // empty batch
        roundtrip(Msg::Score {
            id: 1,
            cols: 10,
            row_nnz: vec![],
            col_idx: vec![],
            values: vec![],
        });
        // a real batch, including an all-zero row and awkward f32 bits
        roundtrip(Msg::Score {
            id: u64::MAX,
            cols: 5,
            row_nnz: vec![2, 0, 1],
            col_idx: vec![0, 4, 2],
            values: vec![0.1, -0.0, f32::MIN_POSITIVE],
        });
        roundtrip(Msg::Scores { id: 7, epoch: 3, margins: vec![] });
        roundtrip(Msg::Scores {
            id: 7,
            epoch: 3,
            margins: vec![0.1 + 0.2, -0.0, f64::MIN_POSITIVE],
        });
        roundtrip(Msg::Publish {
            loss: Loss::Logistic,
            lambda: 1e-4,
            weights: vec![0.1 + 0.2, -1.5],
        });
        roundtrip(Msg::Publish {
            loss: Loss::SquaredHinge,
            lambda: 0.5,
            weights: vec![],
        });
        roundtrip(Msg::Published { epoch: 1 });
        roundtrip(Msg::Published { epoch: u64::MAX });
    }

    #[test]
    fn serving_frame_version_and_bits() {
        // Score carries the version right after the tag, like Setup
        let mut bytes = encode(&Msg::Score {
            id: 1,
            cols: 3,
            row_nnz: vec![1],
            col_idx: vec![0],
            values: vec![1.0],
        });
        bytes[1..5].copy_from_slice(&(PROTO_VERSION + 1).to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert!(err.contains("version mismatch"), "{err}");
        // so does Publish
        let mut bytes = encode(&Msg::Publish {
            loss: Loss::Logistic,
            lambda: 1e-3,
            weights: vec![1.0],
        });
        bytes[1..5].copy_from_slice(&(PROTO_VERSION + 1).to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert!(err.contains("version mismatch"), "{err}");
        // f32 feature values survive bitwise
        for v in [0.1f32, -0.0, f32::MAX, f32::MIN_POSITIVE] {
            let msg = Msg::Score {
                id: 0,
                cols: 1,
                row_nnz: vec![1],
                col_idx: vec![0],
                values: vec![v],
            };
            let Msg::Score { values, .. } = decode(&encode(&msg)).unwrap() else {
                panic!()
            };
            assert_eq!(values[0].to_bits(), v.to_bits());
        }
        // absurd claimed f32 length fails fast instead of allocating
        let mut d = Dec::new(&u64::MAX.to_le_bytes());
        assert!(d.vec_f32().is_err());
    }

    #[test]
    fn serving_frame_accounting() {
        assert_eq!(
            msg_data_bytes(&Msg::Score {
                id: 9,
                cols: 100,
                row_nnz: vec![3, 2],
                col_idx: vec![0, 1, 2, 3, 4],
                values: vec![0.0; 5],
            }),
            20,
            "f32 feature values are data; nnz counts and columns are \
             structure, ids are control"
        );
        assert_eq!(
            msg_data_bytes(&Msg::Scores { id: 9, epoch: 2, margins: vec![0.0; 6] }),
            48
        );
        assert_eq!(
            msg_data_bytes(&Msg::Publish {
                loss: Loss::Logistic,
                lambda: 1e-3,
                weights: vec![0.0; 4],
            }),
            32
        );
        assert_eq!(msg_data_bytes(&Msg::Published { epoch: 5 }), 0);
    }

    #[test]
    fn data_byte_accounting_counts_inline_vectors_only() {
        // inline refs and vector payloads count; register refs, dot
        // lists and scalar aggregates are control traffic
        assert_eq!(
            msg_data_bytes(&Msg::Cmd(Command::Grad {
                loss: Loss::Logistic,
                w: VecRef::Inline(vec![0.0; 5]),
            })),
            40
        );
        assert_eq!(
            msg_data_bytes(&Msg::Cmd(Command::Grad {
                loss: Loss::Logistic,
                w: VecRef::Reg(0),
            })),
            0
        );
        assert_eq!(
            msg_data_bytes(&Msg::Cmd(Command::VecOps {
                ops: vec![VecOp::Scale { dst: 0, a: 2.0 }],
                dots: vec![(0, 0)],
            })),
            0,
            "bookkeeping ops and dot requests are control traffic"
        );
        assert_eq!(
            msg_data_bytes(&Msg::Cmd(Command::SetReg { reg: 0, v: vec![0.0; 3] })),
            24
        );
        assert_eq!(
            msg_data_bytes(&Msg::Reply {
                reply: Reply::Dots { vals: vec![1.0; 8], units: 0.0 },
                secs: 0.25,
                queue_ns: 99,
                page_ns: 0,
            }),
            0,
            "replicated dots (and compute seconds) are scalar aggregates"
        );
        assert_eq!(
            msg_data_bytes(&Msg::Reply {
                reply: Reply::Warm {
                    w: vec![0.0; 4],
                    counts: vec![0.0; 4],
                    units: 1.0,
                },
                secs: 0.0,
                queue_ns: 0,
                page_ns: 0,
            }),
            64
        );
        assert_eq!(
            msg_data_bytes(&Msg::Cmd(Command::FetchTelemetry)),
            0,
            "telemetry flush requests are control traffic"
        );
        assert_eq!(
            msg_data_bytes(&Msg::Reply {
                reply: Reply::Telemetry {
                    spans: vec![crate::metrics::telemetry::Span {
                        name: std::borrow::Cow::Borrowed("cmd:grad"),
                        rank: 0,
                        thread: 0,
                        t_start_ns: 0,
                        t_end_ns: 100,
                        bytes: 1 << 20,
                    }],
                    dropped: 7,
                    units: 0.0,
                },
                secs: 0.0,
                queue_ns: 0,
                page_ns: 0,
            }),
            0,
            "span flushes are control traffic — scalar-only driver holds"
        );
        assert_eq!(
            msg_data_bytes(&Msg::Cmd(Command::TestAuprc { w: VecRef::Reg(3) })),
            0,
            "register-referenced held-out scoring is control traffic"
        );
        assert_eq!(
            msg_data_bytes(&Msg::Reduced {
                reply: Reply::Solve { w: vec![], n: 10, units: 1.0 },
                data_tx: 99,
                data_rx: 99,
                secs: 0.5,
                compute_secs: 0.25,
                queue_ns: 11,
                stall_ns: 22,
                overlap_ns: 33,
                page_ns: 44,
                dots: vec![1.0, 2.0],
            }),
            0,
            "an emptied combine reply is scalar-only"
        );
        assert_eq!(
            msg_data_bytes(&Msg::Finish { sums: vec![vec![0.0; 6], vec![0.0; 6]] }),
            96
        );
        use crate::net::CombineSpec;
        assert_eq!(
            msg_data_bytes(&Msg::Reduce {
                cmd: Command::Hvp { loss: Loss::Logistic, s: VecRef::Reg(1) },
                topology: crate::net::Topology::Tree,
                spec: CombineSpec {
                    weights: vec![0.25; 4],
                    ..CombineSpec::sum_into(2)
                },
            }),
            0,
            "per-rank weights are control scalars, not an m-vector"
        );
    }

    #[test]
    fn truncated_u32_vectors_rejected() {
        let mut e = Enc::new();
        e.vec_vec_u32(&[vec![1, 2, 3], vec![4]]);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.vec_vec_u32().unwrap(), vec![vec![1, 2, 3], vec![4]]);
        // absurd claimed lengths fail fast instead of allocating
        let mut d = Dec::new(&u64::MAX.to_le_bytes());
        assert!(d.vec_u32().is_err());
        let mut d = Dec::new(&u64::MAX.to_le_bytes());
        assert!(d.vec_vec_u32().is_err());
    }

    #[test]
    fn f64_bits_survive_exactly() {
        for v in [0.1 + 0.2, -0.0, f64::MAX, f64::MIN_POSITIVE, 1e-308] {
            let msg = Msg::Cmd(Command::Dirs { d: VecRef::Inline(vec![v]) });
            let Msg::Cmd(Command::Dirs { d: VecRef::Inline(d) }) =
                decode(&encode(&msg)).unwrap()
            else {
                panic!()
            };
            assert_eq!(d[0].to_bits(), v.to_bits());
        }
    }

    #[test]
    fn framing_roundtrip_and_eof() {
        let mut buf = Vec::new();
        let n1 = write_frame(&mut buf, b"hello").unwrap();
        let n2 = write_frame(&mut buf, b"").unwrap();
        assert_eq!(n1, 9);
        assert_eq!(n2, 4);
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello".to_vec());
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), Vec::<u8>::new());
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut bytes = encode(&Msg::Ready {
            m: 1,
            n: 2,
            nnz: 3,
            data_port: 0,
            now_ns: 0,
        });
        // version is the u32 right after the tag byte
        bytes[1..5].copy_from_slice(&(PROTO_VERSION + 1).to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert!(err.contains("version mismatch"), "{err}");
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(decode(&[200]).is_err());
        // trailing garbage
        let mut bytes = encode(&Msg::Shutdown);
        bytes.push(0);
        assert!(decode(&bytes).is_err());
        // truncated vector
        let bytes =
            encode(&Msg::Cmd(Command::Dirs { d: VecRef::Inline(vec![1.0, 2.0]) }));
        assert!(decode(&bytes[..bytes.len() - 4]).is_err());
        // absurd length prefix
        let mut r = std::io::Cursor::new((u32::MAX).to_le_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
        // EOF inside the length prefix is truncation, not a clean close
        let mut r = std::io::Cursor::new(vec![7u8, 0]);
        let err = read_frame(&mut r).unwrap_err();
        assert!(err.contains("truncated"), "{err}");
    }
}
