//! The TCP worker process: one shard, one frame loop.
//!
//! Spawned by [`super::tcp::TcpDriver`] (directly as the `worker` bin
//! or via the `--worker` self-exec fallback). The worker rebuilds its
//! shard from the [`super::WorkerSetup`] recipe using the *same*
//! coordinator pipeline as the in-process driver, then serves commands
//! with the shared [`super::endpoint::exec`] until `Shutdown` or EOF.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;

use super::endpoint::{exec, WorkerState};
use super::wire::{self, Msg};

/// The `--worker --connect host:port` self-exec handshake, shared by
/// every binary that can be re-executed as a worker (see
/// `tcp::resolve_worker_command`). Returns `None` when the args don't
/// request worker mode; otherwise serves and returns the outcome —
/// the caller should exit(0/1) without running its own main.
pub fn serve_if_requested(args: &[String]) -> Option<Result<(), String>> {
    if !args.iter().any(|a| a == "--worker") {
        return None;
    }
    let connect = args
        .iter()
        .position(|a| a == "--connect")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_default();
    if connect.is_empty() {
        return Some(Err("--worker: missing --connect".into()));
    }
    Some(serve(&connect))
}

/// Connect to the driver and serve phases until shutdown. Returns
/// `Err` on protocol or setup failures (after attempting to send an
/// `Abort` so the driver fails fast instead of hanging).
pub fn serve(connect: &str) -> Result<(), String> {
    let stream = TcpStream::connect(connect)
        .map_err(|e| format!("connect to driver at {connect}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let rs = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
    let mut r = BufReader::new(rs);
    let mut w = BufWriter::new(stream);

    let send = |msg: &Msg, w: &mut BufWriter<TcpStream>| -> Result<(), String> {
        wire::send(w, msg)?;
        w.flush().map_err(|e| format!("flush: {e}"))
    };

    // --- setup ---
    let setup = match wire::recv(&mut r)? {
        Some(Msg::Setup(s)) => s,
        Some(other) => return Err(format!("expected Setup, got {other:?}")),
        None => return Err("driver closed before setup".into()),
    };
    let shard = match crate::coordinator::driver::build_worker_shard(&setup) {
        Ok(shard) => shard,
        Err(e) => {
            let _ = send(&Msg::Abort { msg: e.clone() }, &mut w);
            return Err(format!("build shard for rank {}: {e}", setup.rank));
        }
    };
    let mut st = WorkerState::new(setup.rank, setup.p);
    send(
        &Msg::Ready { m: shard.m(), n: shard.n(), nnz: shard.nnz() },
        &mut w,
    )?;

    // --- phase loop ---
    loop {
        let msg = match wire::recv(&mut r)? {
            Some(msg) => msg,
            // driver went away (e.g. it was killed): exit quietly
            None => return Ok(()),
        };
        match msg {
            Msg::Shutdown => return Ok(()),
            Msg::Cmd(cmd) => match exec(shard.as_ref(), &mut st, &cmd) {
                Ok(reply) => send(&Msg::Reply(reply), &mut w)?,
                Err(e) => {
                    let _ = send(&Msg::Abort { msg: e.clone() }, &mut w);
                    return Err(format!("rank {}: {e}", setup.rank));
                }
            },
            other => return Err(format!("unexpected message {other:?}")),
        }
    }
}
