//! The TCP worker process: one shard, one frame loop, and (under the
//! p2p data plane) one side of the rank ⇄ rank mesh.
//!
//! Spawned by [`super::tcp::TcpDriver`] (directly as the `worker` bin
//! or via the `--worker` self-exec fallback). The worker rebuilds its
//! shard from the [`super::WorkerSetup`] recipe using the *same*
//! coordinator pipeline as the in-process driver, then serves commands
//! with the shared [`super::endpoint::exec`] until `Shutdown` or EOF.
//!
//! Control plane: `Setup` → `Ready` → (`Mesh` → `MeshOk` under p2p) →
//! `Cmd`/`Reduce` frames. A `Reduce` frame executes the command,
//! applies the combine spec's per-rank pre-transform, and then — p2p —
//! runs this rank's share of the topology's [`ReducePlan`] over the
//! mesh ([`super::mesh::Mesh::allreduce`]) and completes the combine
//! locally (epilogue, replicated register store, dot products), so the
//! driver receives only scalars; or — star — returns the
//! pre-transformed parts and completes the combine on the driver's
//! `Finish` frame carrying the plan sums.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use super::endpoint::{
    complete_combine, eval_test_auprc, exec, exec_streamed, pre_combine,
    put_combine_vectors, take_combine_vectors, WorkerState,
};
use super::mesh::{Mesh, MeshStats};
use super::topology::RankSchedule;
use super::wire::{self, Msg};
use super::{Combine, Command, DataPlane, Reply, Topology};
use crate::metrics::telemetry;

/// The `--worker --connect host:port` self-exec handshake, shared by
/// every binary that can be re-executed as a worker (see
/// `tcp::resolve_worker_command`). Returns `None` when the args don't
/// request worker mode; otherwise serves and returns the outcome —
/// the caller should exit(0/1) without running its own main.
pub fn serve_if_requested(args: &[String]) -> Option<Result<(), String>> {
    if !args.iter().any(|a| a == "--worker") {
        return None;
    }
    let connect = args
        .iter()
        .position(|a| a == "--connect")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_default();
    if connect.is_empty() {
        return Some(Err("--worker: missing --connect".into()));
    }
    Some(serve(&connect))
}

/// Connect to the driver and serve phases until shutdown. Returns
/// `Err` on protocol or setup failures (after attempting to send an
/// `Abort` so the driver fails fast instead of hanging).
pub fn serve(connect: &str) -> Result<(), String> {
    let stream = TcpStream::connect(connect)
        .map_err(|e| format!("connect to driver at {connect}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let rs = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
    let mut r = BufReader::new(rs);
    let mut w = BufWriter::new(stream);

    let send = |msg: &Msg, w: &mut BufWriter<TcpStream>| -> Result<(), String> {
        wire::send(w, msg)?;
        w.flush().map_err(|e| format!("flush: {e}"))
    };

    // --- setup ---
    let setup = match wire::recv(&mut r)? {
        Some(Msg::Setup(s)) => s,
        Some(other) => return Err(format!("expected Setup, got {other:?}")),
        None => return Err("driver closed before setup".into()),
    };
    let abort = |e: String, w: &mut BufWriter<TcpStream>| -> String {
        let _ = send(&Msg::Abort { msg: e.clone() }, w);
        format!("rank {}: {e}", setup.rank)
    };
    // bind the data-plane listener before Ready so the frame can
    // advertise the port (p2p only)
    let data_listener = if setup.data_plane == DataPlane::P2p {
        let host = setup.p2p_host(setup.rank);
        let port = if setup.p2p_port_base == 0 {
            0
        } else {
            match u16::try_from(setup.rank)
                .ok()
                .and_then(|r| setup.p2p_port_base.checked_add(r))
            {
                Some(port) => port,
                None => {
                    return Err(abort(
                        format!(
                            "p2p_port_base {} + rank {} overflows the port range",
                            setup.p2p_port_base, setup.rank
                        ),
                        &mut w,
                    ))
                }
            }
        };
        match TcpListener::bind((host.as_str(), port)) {
            Ok(l) => Some(l),
            Err(e) => {
                return Err(abort(
                    format!("bind data-plane listener on {host}:{port}: {e}"),
                    &mut w,
                ))
            }
        }
    } else {
        None
    };
    let data_port = match &data_listener {
        Some(l) => l
            .local_addr()
            .map_err(|e| format!("data listener addr: {e}"))?
            .port(),
        None => 0,
    };
    // shard + held-out set + the persistent block pool (sized by the
    // Setup frame's `threads`, spawned once, joined when this function
    // returns — a `Shutdown` frame or driver EOF tears it down cleanly)
    let (shard, test) = match crate::coordinator::driver::build_worker_context(&setup) {
        Ok(ctx) => ctx,
        Err(e) => return Err(abort(format!("build shard: {e}"), &mut w)),
    };
    // telemetry is opt-in per run: the Setup frame carries the switch,
    // and the Ready frame carries this process's monotonic clock sample
    // so the driver can rebase our spans onto its own timeline
    if setup.telemetry {
        telemetry::set_rank(setup.rank);
        telemetry::enable();
    }
    let mut st = WorkerState::new(setup.rank, setup.p);
    send(
        &Msg::Ready {
            m: shard.m(),
            n: shard.n(),
            nnz: shard.nnz(),
            data_port,
            now_ns: telemetry::now_ns(),
        },
        &mut w,
    )?;

    // --- phase loop ---
    let mut mesh: Option<Mesh> = None;
    // compiled mesh schedules plus their overlap-streamability flags,
    // one per (topology, m) seen — reduces are hot-loop operations, the
    // compile is paid once per shape
    let mut scheds: Vec<(Topology, usize, RankSchedule, Vec<bool>)> = Vec::new();
    loop {
        let msg = match wire::recv(&mut r)? {
            Some(msg) => msg,
            // driver went away (e.g. it was killed): exit quietly,
            // dropping the mesh sockets and the data-plane port with us
            None => return Ok(()),
        };
        match msg {
            Msg::Shutdown => return Ok(()),
            Msg::Mesh { addrs } => {
                let Some(listener) = &data_listener else {
                    return Err(abort(
                        "mesh handshake on the star data plane".into(),
                        &mut w,
                    ));
                };
                if addrs.len() != setup.p {
                    return Err(abort(
                        format!("mesh lists {} ranks, P = {}", addrs.len(), setup.p),
                        &mut w,
                    ));
                }
                let established = if setup.p == 1 {
                    Ok(Mesh::solo(setup.rank))
                } else {
                    Mesh::establish(setup.rank, &addrs, listener)
                };
                match established {
                    Ok(mut m) => {
                        m.set_encoding(setup.frame_encoding);
                        mesh = Some(m);
                    }
                    Err(e) => return Err(abort(e, &mut w)),
                }
                send(&Msg::MeshOk, &mut w)?;
            }
            Msg::Probe { rounds, small_m, large_m } => {
                // one-shot link probe for `topology = "auto"`: time a
                // handful of tree-plan allreduces at two sizes over the
                // open mesh. Best-of (min) per size filters scheduler
                // noise; the driver takes the max across ranks because
                // the BSP barrier pays the slowest rank either way.
                let Some(mesh) = &mesh else {
                    return Err(abort("Probe before the mesh handshake".into(), &mut w));
                };
                let _span = telemetry::SpanGuard::open("mesh:probe");
                let mut time_size = |m: usize| -> Result<u64, String> {
                    let idx =
                        cached_sched(&mut scheds, Topology::Tree, m, setup.p, setup.rank);
                    let mut best = u64::MAX;
                    for _ in 0..rounds.max(1) {
                        let mut buf: Vec<f64> =
                            (0..m).map(|i| 1.0 + (i % 7) as f64).collect();
                        let t0 = Instant::now();
                        mesh.allreduce(&mut buf, &scheds[idx].2)?;
                        best = best.min(t0.elapsed().as_nanos() as u64);
                    }
                    Ok(best)
                };
                let timed = time_size(small_m).and_then(|s| time_size(large_m).map(|l| (s, l)));
                match timed {
                    Ok((small_ns, large_ns)) => {
                        send(&Msg::Probed { small_ns, large_ns }, &mut w)?
                    }
                    Err(e) => return Err(abort(e, &mut w)),
                }
            }
            Msg::Cmd(cmd) => {
                // only shard-compute kernels report time, so the
                // `meas_compute_secs` column stays a pure measure of
                // the engine's shard sweeps (no instrumentation, no
                // register bookkeeping)
                let (result, secs) = match &cmd {
                    // the worker owns the held-out set; exec owns only
                    // the shard
                    Command::TestAuprc { w: wref } => {
                        (eval_test_auprc(test.as_ref(), &st, wref), 0.0)
                    }
                    // the rings are process-global, so the transport
                    // (not exec) drains them; flushing happens only at
                    // trace boundaries, never inside the phase loop
                    Command::FetchTelemetry => {
                        let (spans, dropped) = telemetry::collect();
                        (Ok(Reply::Telemetry { spans, dropped, units: 0.0 }), 0.0)
                    }
                    _ if !cmd.is_compute() => {
                        (exec(shard.as_ref(), &mut st, &cmd), 0.0)
                    }
                    _ => {
                        let t0 = Instant::now();
                        let result = exec(shard.as_ref(), &mut st, &cmd);
                        (result, t0.elapsed().as_secs_f64())
                    }
                };
                match result {
                    Ok(reply) => {
                        let queue_ns = shard.take_queue_wait_ns();
                        let page_ns = shard.take_page_stall_ns();
                        send(&Msg::Reply { reply, secs, queue_ns, page_ns }, &mut w)?
                    }
                    Err(e) => return Err(abort(e, &mut w)),
                }
            }
            Msg::Reduce { cmd, topology, spec } => {
                if setup.data_plane == DataPlane::P2p && mesh.is_none() {
                    return Err(abort("Reduce before the mesh handshake".into(), &mut w));
                }
                // compute/communication overlap: when the combine's
                // pre-transform is the identity (no weights, plain
                // WeightedSum) and the phase is a block-streamable
                // kernel, flush finished row-block partials onto the
                // mesh while the remaining blocks are still computing.
                // Eligibility depends only on the command and spec —
                // never on this rank's block count — so every rank
                // takes the same branch and the plan stays symmetric.
                let overlap_ok = setup.overlap
                    && mesh.is_some()
                    && spec.weights.is_empty()
                    && matches!(spec.kind, Combine::WeightedSum)
                    && matches!(&cmd, Command::Grad { .. } | Command::Hvp { .. });
                let mut streamed = None;
                let mut sched_idx = None;
                let (result, compute_secs) = if overlap_ok {
                    let m = shard.m();
                    let idx = cached_sched(&mut scheds, topology, m, setup.p, setup.rank);
                    sched_idx = Some(idx);
                    let mesh_ref = mesh.as_ref().expect("overlap implies mesh");
                    let handle = match mesh_ref.begin_stream(
                        &scheds[idx].2,
                        &scheds[idx].3,
                        shard.stream_block_count(),
                    ) {
                        Ok(h) => h,
                        Err(e) => return Err(abort(e, &mut w)),
                    };
                    let t_exec = Instant::now();
                    let sink = |b: usize, partial: &[f64]| handle.offer(b, partial);
                    let result = exec_streamed(shard.as_ref(), &mut st, &cmd, &sink);
                    let compute_secs = t_exec.elapsed().as_secs_f64();
                    // the overlap window: first partial on the wire →
                    // kernel done (what a blocking reduce would have
                    // serialized after compute instead)
                    let overlap_ns = handle
                        .first_flush()
                        .map(|t0| Instant::now().duration_since(t0).as_nanos() as u64)
                        .unwrap_or(0);
                    streamed = Some((handle, overlap_ns));
                    (result, compute_secs)
                } else {
                    let t_exec = Instant::now();
                    let result = exec(shard.as_ref(), &mut st, &cmd);
                    (result, t_exec.elapsed().as_secs_f64())
                };
                let mut reply = match result {
                    Ok(reply) => reply,
                    Err(e) => return Err(abort(e, &mut w)),
                };
                let mut vectors = match take_combine_vectors(&mut reply) {
                    Ok(v) => v,
                    Err(e) => return Err(abort(e, &mut w)),
                };
                if let Err(e) = pre_combine(&st, &spec, setup.rank, &mut vectors) {
                    return Err(abort(e, &mut w));
                }
                match &mesh {
                    Some(mesh) => {
                        // p2p: execute the plan over the mesh (once per
                        // vector — the warm start reduces two), then
                        // complete the combine locally. Every rank ends
                        // holding the combined result in its registers;
                        // the driver gets scalars only.
                        let m = vectors[0].len();
                        let idx = match sched_idx {
                            Some(i) => i,
                            None => cached_sched(
                                &mut scheds,
                                topology,
                                m,
                                setup.p,
                                setup.rank,
                            ),
                        };
                        let mut stats = MeshStats::default();
                        let mut overlap_ns = 0u64;
                        match streamed {
                            Some((handle, ons)) => {
                                // streamable phases reduce exactly one
                                // vector; the handle completes it
                                match mesh.allreduce_overlap(
                                    &mut vectors[0],
                                    &scheds[idx].2,
                                    &scheds[idx].3,
                                    handle,
                                ) {
                                    Ok(s) => stats.merge(&s),
                                    Err(e) => return Err(abort(e, &mut w)),
                                }
                                overlap_ns = ons;
                            }
                            None => {
                                for vector in vectors.iter_mut() {
                                    match mesh.allreduce(vector, &scheds[idx].2) {
                                        Ok(s) => stats.merge(&s),
                                        Err(e) => return Err(abort(e, &mut w)),
                                    }
                                }
                            }
                        }
                        // the mesh left the plan sums replicated here
                        let dots = match complete_combine(&mut st, &spec, &vectors) {
                            Ok(dots) => dots,
                            Err(e) => return Err(abort(e, &mut w)),
                        };
                        send(
                            &Msg::Reduced {
                                reply,
                                data_tx: stats.tx,
                                data_rx: stats.rx,
                                secs: stats.secs,
                                compute_secs,
                                queue_ns: shard.take_queue_wait_ns(),
                                stall_ns: (stats.stall_secs * 1e9) as u64,
                                overlap_ns,
                                page_ns: shard.take_page_stall_ns(),
                                dots,
                            },
                            &mut w,
                        )?;
                    }
                    None => {
                        // star: the pre-transformed parts ride the
                        // reply slots to the driver's plan execution;
                        // the epilogue runs here on the Finish sums so
                        // the register file matches the p2p ranks'.
                        if let Err(e) = put_combine_vectors(&mut reply, vectors) {
                            return Err(abort(e, &mut w));
                        }
                        send(
                            &Msg::Reduced {
                                reply,
                                data_tx: 0,
                                data_rx: 0,
                                secs: 0.0,
                                compute_secs,
                                queue_ns: shard.take_queue_wait_ns(),
                                stall_ns: 0,
                                overlap_ns: 0,
                                page_ns: shard.take_page_stall_ns(),
                                dots: Vec::new(),
                            },
                            &mut w,
                        )?;
                        let sums = match wire::recv(&mut r)? {
                            Some(Msg::Finish { sums }) => sums,
                            Some(Msg::Shutdown) | None => return Ok(()),
                            Some(other) => {
                                return Err(abort(
                                    format!("expected Finish, got {other:?}"),
                                    &mut w,
                                ))
                            }
                        };
                        let dots = match complete_combine(&mut st, &spec, &sums) {
                            Ok(dots) => dots,
                            Err(e) => return Err(abort(e, &mut w)),
                        };
                        send(&Msg::Finished { dots }, &mut w)?;
                    }
                }
            }
            other => return Err(format!("unexpected message {other:?}")),
        }
    }
}

/// Index of the compiled `(topology, m)` schedule in the worker's
/// cache, compiling the rank schedule plus its overlap-streamability
/// flags on first use.
fn cached_sched(
    scheds: &mut Vec<(Topology, usize, RankSchedule, Vec<bool>)>,
    topology: Topology,
    m: usize,
    p: usize,
    rank: usize,
) -> usize {
    if let Some(i) = scheds.iter().position(|(t, mm, _, _)| *t == topology && *mm == m)
    {
        return i;
    }
    let _span = telemetry::SpanGuard::open("plan:compile");
    let plan = topology.plan(p, m);
    let sched = plan.rank_schedule(rank);
    let flags = plan.overlap_flags(rank);
    scheds.push((topology, m, sched, flags));
    scheds.len() - 1
}
