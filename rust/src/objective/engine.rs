//! The intra-worker parallel compute engine: a persistent deterministic
//! thread pool plus cache-sized row blocking for the `ShardCompute` hot
//! loops.
//!
//! After the combine plane made every m-vector collective worker-
//! resident, a worker's *single-threaded* sweep over its shard became
//! the critical path. This module makes that sweep block-parallel while
//! keeping the bitwise-reproducibility contract the topology plans
//! already pin for communication:
//!
//! * **Blocking** is a pure function of the shard ([`row_blocks`]):
//!   contiguous row ranges closed when a block reaches
//!   [`TARGET_BLOCK_NNZ`] stored nonzeros (≈ a quarter MiB of CSR
//!   payload — L2-resident on every deployment target). The thread
//!   count never influences where blocks fall.
//! * **Execution** is dynamic (threads grab the next unclaimed block
//!   index from an atomic counter), but every block writes only its own
//!   output slot, so *which* thread computes a block cannot affect any
//!   bit of it.
//! * **Merging** is fixed-order: per-block partial sums are folded in
//!   block order (block 0 first, always), and per-coordinate gradient
//!   merges add block buffers in block order per coordinate. Therefore
//!   `threads = T` is bitwise identical to `threads = 1` for every
//!   kernel — the determinism contract `rust/tests/proptest_engine.rs`
//!   pins across adversarial blockings.
//!
//! The pool itself ([`ComputePool`]) is std-only and persistent: worker
//! threads are spawned once (per worker process at `Setup`, or once per
//! in-process cluster) and parked on a condvar between kernels, so the
//! CG/line-search hot loops pay no spawn/join latency. `threads = 1`
//! (the default) spawns no OS threads at all and runs inline — the
//! seed's behaviour.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::linalg::Csr;
use crate::loss::Loss;
use crate::metrics::telemetry::SpanGuard;

/// Close a row block once it holds this many stored nonzeros (values +
/// column indices ≈ 8 bytes/nnz → ~256 KiB per block). Small test
/// shards fall below the target and get exactly one block, which makes
/// the blocked kernels bit-identical to the historical unblocked loops
/// there.
pub const TARGET_BLOCK_NNZ: usize = 32_768;

/// Upper bound on the default block count: the gradient/Hvp kernels
/// materialize one m-width accumulator per block, so huge shards widen
/// their blocks instead of multiplying buffers (transient memory and
/// merge traffic stay ≤ MAX_BLOCKS·m while dynamic claiming still has
/// plenty of slack over any sane thread count). Like the target, a
/// pure function of the shard — never of T.
pub const MAX_BLOCKS: usize = 64;

/// Coordinate-chunk width of the fixed-order gradient merge (a pure
/// constant — chunk boundaries never depend on the thread count, and
/// per-coordinate sums are independent, so chunking cannot change bits).
const MERGE_CHUNK: usize = 4_096;

// ---------------------------------------------------------------------------
// Row blocking
// ---------------------------------------------------------------------------

/// Pre-split a CSR matrix into contiguous row blocks of roughly
/// `target_nnz` stored nonzeros (at least one row per block; empty rows
/// are carried along with their neighbours, and an all-empty tail rides
/// with the last block — so a shard never splits into more than
/// ⌈nnz / target⌉ blocks). Depends only on the matrix shape — never on
/// the thread count.
pub fn row_blocks_with_target(x: &Csr, target_nnz: usize) -> Vec<Range<usize>> {
    let target = target_nnz.max(1);
    let mut blocks: Vec<Range<usize>> = Vec::new();
    let mut start = 0usize;
    let mut nnz = 0usize;
    for i in 0..x.rows {
        nnz += x.row_nnz(i);
        if nnz >= target {
            blocks.push(start..i + 1);
            start = i + 1;
            nnz = 0;
        }
    }
    if start < x.rows {
        match blocks.last_mut() {
            // a tail of empty rows extends the previous block instead
            // of opening a (MAX_BLOCKS + 1)-th buffer
            Some(last) if nnz == 0 => last.end = x.rows,
            _ => blocks.push(start..x.rows),
        }
    }
    blocks
}

/// The default blocking: [`TARGET_BLOCK_NNZ`]-sized blocks, widened so
/// no shard splits into more than [`MAX_BLOCKS`] of them.
pub fn row_blocks(x: &Csr) -> Vec<Range<usize>> {
    let target = TARGET_BLOCK_NNZ.max(x.nnz().div_ceil(MAX_BLOCKS));
    row_blocks_with_target(x, target)
}

/// Resolve a configured `threads` value: 0 means one thread per
/// available core, anything else is taken literally (min 1). Results
/// are bitwise independent of the resolution either way.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

// ---------------------------------------------------------------------------
// The persistent thread pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Job>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    available: Condvar,
    /// nanoseconds helper jobs sat queued before a thread picked them
    /// up, accumulated since the last [`ComputePool::take_queue_wait_ns`]
    /// (the `queue_wait_secs` trace column)
    queue_wait_ns: AtomicU64,
}

/// A persistent worker pool executing index-addressed block jobs.
/// `ComputePool::new(1)` (and [`ComputePool::serial`]) spawn no OS
/// threads and run everything inline on the caller.
pub struct ComputePool {
    /// configured parallelism T (the caller participates, so T − 1
    /// helper threads are spawned)
    threads: usize,
    shared: Option<Arc<PoolShared>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl std::fmt::Debug for ComputePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ComputePool")
            .field("threads", &self.threads)
            .finish()
    }
}

/// Per-`run` coordination: the next unclaimed block index, the number
/// of helper jobs still holding the borrowed closure, and a panic flag.
struct RunState {
    next: AtomicUsize,
    n: usize,
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl RunState {
    fn finish_one(&self) {
        let mut pending = self.pending.lock().unwrap();
        *pending -= 1;
        self.done.notify_all();
    }

    fn wait_idle(&self) {
        let mut pending = self.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.done.wait(pending).unwrap();
        }
    }
}

/// Decrements the run's pending count when dropped — keeps the caller's
/// `wait_idle` honest even if a helper job unwinds.
struct FinishGuard(Arc<RunState>);

impl Drop for FinishGuard {
    fn drop(&mut self) {
        self.0.finish_one();
    }
}

/// Blocks until every helper job of a run has retired — runs in `Drop`
/// so an unwinding caller still outlives every borrow the helpers hold.
struct WaitGuard<'a>(&'a RunState);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait_idle();
    }
}

impl ComputePool {
    /// A pool of parallelism `threads` (clamped to ≥ 1). `threads − 1`
    /// helper OS threads are spawned once and live until the pool is
    /// dropped; the calling thread is always the T-th worker.
    pub fn new(threads: usize) -> Arc<ComputePool> {
        let threads = threads.max(1);
        if threads == 1 {
            return Arc::new(ComputePool {
                threads,
                shared: None,
                handles: Mutex::new(Vec::new()),
            });
        }
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                shutdown: false,
            }),
            available: Condvar::new(),
            queue_wait_ns: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for _ in 0..threads - 1 {
            let shared = shared.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let mut state = shared.state.lock().unwrap();
                    loop {
                        if let Some(job) = state.queue.pop_front() {
                            break job;
                        }
                        if state.shutdown {
                            return;
                        }
                        state = shared.available.wait(state).unwrap();
                    }
                };
                // jobs are panic-isolated by their own catch_unwind
                job();
            }));
        }
        Arc::new(ComputePool {
            threads,
            shared: Some(shared),
            handles: Mutex::new(handles),
        })
    }

    /// The inline (no OS threads) pool — the seed's serial behaviour.
    pub fn serial() -> Arc<ComputePool> {
        ComputePool::new(1)
    }

    /// Configured parallelism T.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Drain the accumulated helper-job queue-wait nanoseconds (the
    /// time jobs sat in the pool queue before a thread claimed them).
    /// Always 0 on the serial pool — nothing ever queues inline.
    pub fn take_queue_wait_ns(&self) -> u64 {
        match &self.shared {
            Some(shared) => shared.queue_wait_ns.swap(0, Ordering::Relaxed),
            None => 0,
        }
    }

    /// Run `f(i)` for every `i in 0..n`, spread over the pool's threads
    /// (the caller participates). Returns when every call has finished.
    /// Indices are claimed dynamically, so callers must make `f(i)`
    /// write only into index-`i` state — then the output is identical
    /// for every thread count by construction.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        let Some(shared) = &self.shared else {
            for i in 0..n {
                f(i);
            }
            return;
        };
        if n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let helpers = (self.threads - 1).min(n - 1);
        let run = Arc::new(RunState {
            next: AtomicUsize::new(0),
            n,
            pending: Mutex::new(helpers),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the lifetime of `f` is erased so helper jobs can be
        // queued as 'static. Every helper decrements `pending` when it
        // retires (FinishGuard runs even on unwind) and this function
        // cannot return — or unwind past — `WaitGuard` below before
        // `pending` reaches 0, so no helper can touch `f` after this
        // frame dies.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                f_ref,
            )
        };
        {
            let mut state = shared.state.lock().unwrap();
            for _ in 0..helpers {
                let run = run.clone();
                let pool_shared = shared.clone();
                let t_enqueue = Instant::now();
                state.queue.push_back(Box::new(move || {
                    pool_shared.queue_wait_ns.fetch_add(
                        t_enqueue.elapsed().as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    let _span = SpanGuard::open("pool:job");
                    let _finish = FinishGuard(run.clone());
                    let outcome = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| loop {
                            let i = run.next.fetch_add(1, Ordering::Relaxed);
                            if i >= run.n {
                                break;
                            }
                            f_static(i);
                        }),
                    );
                    if outcome.is_err() {
                        run.panicked.store(true, Ordering::Relaxed);
                    }
                }));
            }
            shared.available.notify_all();
        }
        {
            let _wait = WaitGuard(run.as_ref());
            let _span = SpanGuard::open("pool:run");
            // the caller is the T-th worker
            loop {
                let i = run.next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f_ref(i);
            }
            // _wait drops here: block until the helpers retire
        }
        if run.panicked.load(Ordering::Relaxed) {
            panic!("compute pool: a block job panicked");
        }
    }

    /// Run `f(i)` over `0..n` collecting one result per index (results
    /// land in index order regardless of execution order).
    pub fn map<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.run(n, |i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().unwrap())
            .collect()
    }

    /// Run `f(i, slice_i)` over pre-split disjoint mutable slices (one
    /// per index). The slices are handed out by index, so writes stay
    /// disjoint and the result is thread-count-independent.
    pub fn run_over_slices<T, F>(&self, parts: Vec<&mut [T]>, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let slots: Vec<Mutex<Option<&mut [T]>>> =
            parts.into_iter().map(|p| Mutex::new(Some(p))).collect();
        self.run(slots.len(), |i| {
            let part = slots[i].lock().unwrap().take().unwrap();
            f(i, part);
        });
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            shared.state.lock().unwrap().shutdown = true;
            shared.available.notify_all();
        }
        for handle in self.handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Fixed-order merge helpers
// ---------------------------------------------------------------------------

/// Split a mutable slice into per-`ranges` sub-slices (the ranges must
/// be contiguous, in order and cover `0..buf.len()` — row blocks are).
pub fn split_by_ranges<'a, T>(
    buf: &'a mut [T],
    ranges: &[Range<usize>],
) -> Vec<&'a mut [T]> {
    let mut parts = Vec::with_capacity(ranges.len());
    let mut rest = buf;
    let mut consumed = 0usize;
    for r in ranges {
        debug_assert_eq!(r.start, consumed, "ranges must be contiguous");
        let (head, tail) = rest.split_at_mut(r.end - r.start);
        parts.push(head);
        rest = tail;
        consumed = r.end;
    }
    debug_assert!(rest.is_empty(), "ranges must cover the whole slice");
    parts
}

/// out[j] = Σ_b bufs[b][j], summed in block order for every coordinate
/// (block 0 is copied, later blocks added — bitwise independent of the
/// thread count because per-coordinate sums never interleave). The
/// merge is chunk-parallel over coordinates with fixed chunk bounds.
pub fn merge_block_sums(pool: &ComputePool, bufs: &[Vec<f64>], out: &mut [f64]) {
    let Some(first) = bufs.first() else {
        out.fill(0.0);
        return;
    };
    debug_assert!(bufs.iter().all(|b| b.len() == out.len()));
    debug_assert_eq!(first.len(), out.len());
    if bufs.len() == 1 {
        out.copy_from_slice(first);
        return;
    }
    let m = out.len();
    let chunks: Vec<Range<usize>> = (0..m)
        .step_by(MERGE_CHUNK)
        .map(|s| s..(s + MERGE_CHUNK).min(m))
        .collect();
    let parts = split_by_ranges(out, &chunks);
    pool.run_over_slices(parts, |c, part| {
        let lo = chunks[c].start;
        part.copy_from_slice(&bufs[0][lo..lo + part.len()]);
        for buf in &bufs[1..] {
            for (j, slot) in part.iter_mut().enumerate() {
                *slot += buf[lo + j];
            }
        }
    });
}

/// Fold per-block scalar partials in block order (partial 0 is the
/// seed, so a single block reproduces the unblocked sum bit for bit).
pub fn fold_block_scalars(parts: &[f64]) -> f64 {
    let mut it = parts.iter();
    let Some(&first) = it.next() else { return 0.0 };
    it.fold(first, |acc, &v| acc + v)
}

// ---------------------------------------------------------------------------
// Lane-chunked line-search accumulation
// ---------------------------------------------------------------------------

use crate::linalg::LANES;

/// (φ, φ') over one block of per-row line-search terms, accumulated
/// with the canonical lane-chunked DAG (see [`crate::linalg::LANES`]):
/// rows are consumed in fixed chunks of `LANES` into `LANES`
/// independent (φ, φ') accumulator pairs, the lanes fold pairwise
/// `(a0 + a1) + (a2 + a3)`, and the `n % LANES` remainder rows are
/// added sequentially onto the folded sums. `term(k)` yields row `k`'s
/// (p, d) contribution; the chunk bounds depend only on `n`, so the
/// result is a pure function of the terms — not of threads or of the
/// `simd` toggle (both kernel paths call this same fold).
#[inline]
pub fn linesearch_lanes_fold(
    n: usize,
    term: impl Fn(usize) -> (f64, f64),
) -> (f64, f64) {
    let chunks = n / LANES;
    let mut pa = [0.0f64; LANES];
    let mut da = [0.0f64; LANES];
    for t in 0..chunks {
        for l in 0..LANES {
            let (p, d) = term(t * LANES + l);
            pa[l] += p;
            da[l] += d;
        }
    }
    let mut phi = (pa[0] + pa[1]) + (pa[2] + pa[3]);
    let mut dphi = (da[0] + da[1]) + (da[2] + da[3]);
    for k in chunks * LANES..n {
        let (p, d) = term(k);
        phi += p;
        dphi += d;
    }
    (phi, dphi)
}

/// (φ, φ') over one block's packed (z, e, y, c) quadruples — the plan's
/// per-trial kernel. `simd = on` streams the packed buffer in
/// `chunks_exact(4·LANES)` strides (fixed-trip inner loops for the
/// vectorizer); `simd = off` is the indexed reference. Both compute the
/// [`linesearch_lanes_fold`] DAG bit for bit.
#[inline]
pub fn linesearch_packed_block(
    loss: Loss,
    t: f64,
    packed: &[f64],
    simd: bool,
) -> (f64, f64) {
    debug_assert_eq!(packed.len() % 4, 0);
    let n = packed.len() / 4;
    if !simd {
        return linesearch_lanes_fold(n, |k| {
            let q = &packed[4 * k..4 * k + 4];
            loss.linesearch_term(q[0], q[1], q[2], q[3], t)
        });
    }
    let mut pa = [0.0f64; LANES];
    let mut da = [0.0f64; LANES];
    let mut it = packed.chunks_exact(4 * LANES);
    for quads in &mut it {
        for l in 0..LANES {
            let q = &quads[4 * l..4 * l + 4];
            let (p, d) = loss.linesearch_term(q[0], q[1], q[2], q[3], t);
            pa[l] += p;
            da[l] += d;
        }
    }
    let mut phi = (pa[0] + pa[1]) + (pa[2] + pa[3]);
    let mut dphi = (da[0] + da[1]) + (da[2] + da[3]);
    for q in it.remainder().chunks_exact(4) {
        let (p, d) = loss.linesearch_term(q[0], q[1], q[2], q[3], t);
        phi += p;
        dphi += d;
    }
    (phi, dphi)
}

// ---------------------------------------------------------------------------
// The reusable line-search evaluation plan
// ---------------------------------------------------------------------------

/// Packed per-row line-search inputs: for each example the quadruple
/// (z_i, e_i, y_i, c_i), gathered once per search (when the direction
/// margins are cached) and reused across every trial step t — each
/// Armijo–Wolfe probe then streams a single contiguous buffer instead
/// of four parallel arrays. Evaluation is block-parallel with the same
/// fixed-order merge as the plain kernel, and the per-row arithmetic is
/// shared ([`Loss::linesearch_term`]), so the plan's value is bitwise
/// identical to [`super::ShardCompute::linesearch_eval`].
#[derive(Clone, Debug)]
pub struct LinesearchPlan {
    blocks: Vec<Range<usize>>,
    /// AoS layout: packed[4i..4i+4] = (z, e, y, c) of example i
    packed: Vec<f64>,
    pool: Arc<ComputePool>,
    /// kernel implementation toggle (never the bits) — see
    /// [`linesearch_packed_block`]
    simd: bool,
}

impl LinesearchPlan {
    /// Gather (z, e, y, c) into the packed buffer. `blocks` is the
    /// shard's row blocking; `simd` picks the per-trial kernel
    /// implementation (bitwise-identical either way).
    pub fn build(
        blocks: &[Range<usize>],
        pool: Arc<ComputePool>,
        simd: bool,
        z: &[f64],
        e: &[f64],
        y: &[f64],
        c: &[f64],
    ) -> LinesearchPlan {
        let n = z.len();
        debug_assert_eq!(e.len(), n);
        debug_assert_eq!(y.len(), n);
        debug_assert_eq!(c.len(), n);
        let mut packed = vec![0.0; 4 * n];
        {
            let chunks: Vec<Range<usize>> =
                blocks.iter().map(|b| 4 * b.start..4 * b.end).collect();
            let parts = split_by_ranges(&mut packed, &chunks);
            pool.run_over_slices(parts, |b, part| {
                let rows = &blocks[b];
                for (k, i) in rows.clone().enumerate() {
                    part[4 * k] = z[i];
                    part[4 * k + 1] = e[i];
                    part[4 * k + 2] = y[i];
                    part[4 * k + 3] = c[i];
                }
            });
        }
        LinesearchPlan {
            blocks: blocks.to_vec(),
            packed,
            pool,
            simd,
        }
    }

    /// Number of packed examples.
    pub fn n(&self) -> usize {
        self.packed.len() / 4
    }

    /// (φ(t), φ'(t)) over the packed buffer — one trial step of the
    /// search, reusing the gathered blocks.
    pub fn eval(&self, loss: Loss, t: f64) -> (f64, f64) {
        let _span = SpanGuard::open("linesearch:trial");
        let nb = self.blocks.len();
        let partials = self.pool.map(nb, |b| {
            let rows = &self.blocks[b];
            let packed = &self.packed[4 * rows.start..4 * rows.end];
            linesearch_packed_block(loss, t, packed, self.simd)
        });
        let phis: Vec<f64> = partials.iter().map(|&(p, _)| p).collect();
        let dphis: Vec<f64> = partials.iter().map(|&(_, d)| d).collect();
        (fold_block_scalars(&phis), fold_block_scalars(&dphis))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_every_index_once() {
        for threads in [1usize, 2, 4] {
            let pool = ComputePool::new(threads);
            for n in [0usize, 1, 2, 3, 7, 64] {
                let hits: Vec<AtomicU64> =
                    (0..n).map(|_| AtomicU64::new(0)).collect();
                pool.run(n, |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                    "threads={threads} n={n}"
                );
            }
        }
    }

    #[test]
    fn queue_wait_accumulates_and_drains() {
        // serial pool: nothing ever queues
        let serial = ComputePool::serial();
        serial.run(8, |_| {});
        assert_eq!(serial.take_queue_wait_ns(), 0);
        // threaded pool: jobs were enqueued, so some (possibly tiny)
        // wait accumulated, and take() drains it to zero
        let pool = ComputePool::new(3);
        pool.run(64, |i| {
            std::hint::black_box(i * i);
        });
        let _ = pool.take_queue_wait_ns();
        assert_eq!(pool.take_queue_wait_ns(), 0, "take drains the counter");
    }

    #[test]
    fn pool_map_lands_in_index_order() {
        for threads in [1usize, 3] {
            let pool = ComputePool::new(threads);
            let out = pool.map(17, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn pool_is_reusable_across_many_runs() {
        let pool = ComputePool::new(4);
        for round in 0..50 {
            let sum = AtomicU64::new(0);
            pool.run(round % 9, |i| {
                sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
            let n = (round % 9) as u64;
            assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
        }
        assert_eq!(pool.threads(), 4);
    }

    #[test]
    fn pool_propagates_block_panics() {
        let pool = ComputePool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(16, |i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err());
        // the pool survives a panicked run
        let sum = AtomicU64::new(0);
        pool.run(4, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn run_over_slices_writes_disjointly() {
        let pool = ComputePool::new(2);
        let mut buf = vec![0u32; 10];
        let ranges = vec![0..3usize, 3..3, 3..10];
        let parts = split_by_ranges(&mut buf, &ranges);
        assert_eq!(parts.iter().map(|p| p.len()).collect::<Vec<_>>(), [3, 0, 7]);
        pool.run_over_slices(parts, |i, part| {
            for slot in part.iter_mut() {
                *slot = i as u32 + 1;
            }
        });
        assert_eq!(buf, [1, 1, 1, 3, 3, 3, 3, 3, 3, 3]);
    }

    #[test]
    fn blocking_is_threads_independent_and_covers_rows() {
        let rows: Vec<Vec<(u32, f32)>> = (0..100)
            .map(|i| (0..(i % 7)).map(|k| (k as u32, 1.0)).collect())
            .collect();
        let x = Csr::from_rows(8, &rows);
        let blocks = row_blocks_with_target(&x, 10);
        assert!(!blocks.is_empty());
        assert_eq!(blocks[0].start, 0);
        assert_eq!(blocks.last().unwrap().end, 100);
        for pair in blocks.windows(2) {
            assert_eq!(pair[0].end, pair[1].start, "blocks must be contiguous");
        }
        // blocking is a function of the matrix only — recomputing gives
        // identical ranges
        assert_eq!(blocks, row_blocks_with_target(&x, 10));
        // an all-empty tail extends the last block instead of opening a
        // fresh one (keeps the block count ≤ ⌈nnz / target⌉)
        let tailed = Csr::from_rows(
            4,
            &[vec![(0, 1.0)], vec![(1, 1.0)], vec![], vec![], vec![]],
        );
        let blocks = row_blocks_with_target(&tailed, 1);
        assert_eq!(blocks, vec![0..1, 1..5]);
        // a small matrix falls in one default block
        assert_eq!(row_blocks(&x).len(), 1);
        // empty matrix → no blocks
        assert!(row_blocks(&Csr::from_rows(4, &[])).is_empty());
    }

    #[test]
    fn default_blocking_caps_block_count() {
        // past MAX_BLOCKS·TARGET_BLOCK_NNZ nonzeros the blocks widen
        // instead of multiplying (the kernels hold one m-width buffer
        // per block, so the cap bounds transient memory)
        let nnz_per_row = 32usize;
        let rows_needed = (MAX_BLOCKS * TARGET_BLOCK_NNZ) / nnz_per_row + 1_000;
        let row: Vec<(u32, f32)> = (0..nnz_per_row as u32).map(|c| (c, 1.0)).collect();
        let rows = vec![row; rows_needed];
        let x = Csr::from_rows(64, &rows);
        let blocks = row_blocks(&x);
        assert!(
            blocks.len() <= MAX_BLOCKS,
            "{} blocks for {} nnz",
            blocks.len(),
            x.nnz()
        );
        assert!(blocks.len() > MAX_BLOCKS / 2, "cap should stay near-saturated");
        assert_eq!(blocks.last().unwrap().end, rows_needed);
    }

    #[test]
    fn merge_block_sums_is_block_ordered() {
        let pool = ComputePool::serial();
        let bufs = vec![vec![1.0, -0.0, 2.0], vec![0.5, 0.0, -2.0]];
        let mut out = vec![9.0; 3];
        merge_block_sums(&pool, &bufs, &mut out);
        assert_eq!(out, vec![1.5, 0.0, 0.0]);
        // a single block is copied verbatim — even -0.0 survives
        let one = vec![vec![-0.0, 3.0]];
        let mut out = vec![0.0; 2];
        merge_block_sums(&pool, &one, &mut out);
        assert_eq!(out[0].to_bits(), (-0.0f64).to_bits());
        // no blocks → zeros
        let mut out = vec![5.0; 2];
        merge_block_sums(&pool, &[], &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn fold_block_scalars_seeds_with_first() {
        assert_eq!(fold_block_scalars(&[]), 0.0);
        assert_eq!(fold_block_scalars(&[-0.0]).to_bits(), (-0.0f64).to_bits());
        assert_eq!(fold_block_scalars(&[1.0, 2.0, 3.0]), 6.0);
    }

    #[test]
    fn packed_linesearch_simd_matches_reference_bitwise() {
        let mut rng = crate::util::rng::Pcg64::new(0xF01D);
        for loss in [Loss::Logistic, Loss::SquaredHinge] {
            // ragged lengths: empty, below a lane, one chunk, ragged tails
            for n in [0usize, 1, 3, 4, 5, 15, 16, 17, 97] {
                let packed: Vec<f64> = (0..4 * n)
                    .map(|k| match k % 4 {
                        2 => {
                            if rng.below(2) == 0 {
                                1.0
                            } else {
                                -1.0
                            }
                        }
                        3 => rng.normal().abs() + 0.1,
                        _ => rng.normal(),
                    })
                    .collect();
                for t in [0.0, 0.5, 1.0] {
                    let (p0, d0) = linesearch_packed_block(loss, t, &packed, false);
                    let (p1, d1) = linesearch_packed_block(loss, t, &packed, true);
                    assert_eq!(p0.to_bits(), p1.to_bits(), "{loss:?} n={n} t={t}");
                    assert_eq!(d0.to_bits(), d1.to_bits(), "{loss:?} n={n} t={t}");
                }
            }
        }
    }

    #[test]
    fn merge_is_bitwise_identical_across_thread_counts() {
        let mut rng = crate::util::rng::Pcg64::new(7);
        let bufs: Vec<Vec<f64>> = (0..5)
            .map(|_| (0..10_000).map(|_| rng.normal()).collect())
            .collect();
        let serial = ComputePool::serial();
        let mut want = vec![0.0; 10_000];
        merge_block_sums(&serial, &bufs, &mut want);
        for threads in [2usize, 4, 8] {
            let pool = ComputePool::new(threads);
            let mut got = vec![0.0; 10_000];
            merge_block_sums(&pool, &bufs, &mut got);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }
}
