//! The regularized risk functional of eq. (8) and the per-shard compute
//! backends.
//!
//! f(w) = λ/2‖w‖² + Σ_p L_p(w),   L_p(w) = Σ_{i∈I_p} c_i·l(w·x_i, y_i)
//!
//! The regularizer belongs to the *global* objective and is added once
//! by whoever aggregates (master); shards only ever compute weighted
//! data losses. [`ShardCompute`] is the backend trait: the native CSR
//! implementation lives here, the AOT/PJRT dense-block implementation
//! in [`crate::runtime`] — methods are backend-agnostic.

pub mod engine;

use std::sync::Arc;

use crate::data::Dataset;
use crate::linalg::{self, Csr};
use crate::loss::Loss;

use engine::{ComputePool, LinesearchPlan};

/// One node's slice of the data (plus per-example weights for the
/// resampling extension; all 1.0 under a plain partition).
#[derive(Clone, Debug)]
pub struct Shard {
    pub x: Csr,
    pub y: Vec<f64>,
    pub c: Vec<f64>,
}

impl Shard {
    pub fn from_dataset(ds: &Dataset, rows: &[usize], weights: &[f64]) -> Shard {
        assert_eq!(rows.len(), weights.len());
        Shard {
            x: ds.x.select_rows(rows),
            y: rows.iter().map(|&i| ds.y[i]).collect(),
            c: weights.to_vec(),
        }
    }

    pub fn whole(ds: &Dataset) -> Shard {
        Shard {
            x: ds.x.clone(),
            y: ds.y.clone(),
            c: vec![1.0; ds.n()],
        }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }
}

/// Per-example sparse row access for example-wise methods (dual
/// coordinate ascent, the SGD warm start). Resident backends hand out
/// direct CSR row views; the paged backend routes each call through a
/// one-block cache — same rows, same bits, different residency.
pub trait ExampleRows: Sync {
    fn n(&self) -> usize;
    fn y(&self, i: usize) -> f64;
    fn c(&self, i: usize) -> f64;
    fn row_dot(&self, i: usize, w: &[f64]) -> f64;
    fn row_axpy(&self, i: usize, a: f64, w: &mut [f64]);
    fn row_norm_sq(&self, i: usize) -> f64;
}

impl ExampleRows for Shard {
    fn n(&self) -> usize {
        self.x.rows
    }

    fn y(&self, i: usize) -> f64 {
        self.y[i]
    }

    fn c(&self, i: usize) -> f64 {
        self.c[i]
    }

    fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        self.x.row_dot(i, w)
    }

    fn row_axpy(&self, i: usize, a: f64, w: &mut [f64]) {
        self.x.row_axpy(i, a, w)
    }

    fn row_norm_sq(&self, i: usize) -> f64 {
        self.x.row_norm_sq(i)
    }
}

/// Backend-agnostic per-shard computations. All vector arguments are
/// feature-dimension unless stated otherwise.
pub trait ShardCompute: Send + Sync {
    fn n(&self) -> usize;
    fn m(&self) -> usize;
    fn nnz(&self) -> usize;

    /// (Σ c·l(z, y), Xᵀ(c·l'(z, y)), z): the gradient pass.
    /// z = X·w is returned because Algorithm 2 caches it as a by-product.
    fn loss_grad(&self, loss: Loss, w: &[f64]) -> (f64, Vec<f64>, Vec<f64>);

    /// Σ c·l(z, y) only (line-search full evaluations when margins are
    /// recomputed; prefer `linesearch_eval` on cached margins).
    fn loss_value(&self, loss: Loss, w: &[f64]) -> f64 {
        self.loss_grad(loss, w).0
    }

    /// e = X·d (one pass; Algorithm 2 step 9).
    fn margins(&self, d: &[f64]) -> Vec<f64>;

    /// Gauss–Newton Hessian-vector product at cached margins z:
    /// Hs = Xᵀ(c ⊙ l''(z, y) ⊙ (X·s)).
    fn hvp(&self, loss: Loss, z: &[f64], s: &[f64]) -> Vec<f64>;

    /// (φ(t), φ'(t)) over cached (z, e): φ(t) = Σ c·l(z + t·e, y).
    fn linesearch_eval(&self, loss: Loss, z: &[f64], e: &[f64], t: f64) -> (f64, f64);

    /// Build a reusable packed evaluation plan for a line search over
    /// cached (z, e): the per-row (z, e, y, c) gather is paid once and
    /// every trial step of the same search streams the packed blocks
    /// ([`engine::LinesearchPlan`]), bitwise identical to
    /// `linesearch_eval`. `None` for backends without per-example
    /// access (the PJRT dense backend) — callers fall back to
    /// `linesearch_eval`.
    fn linesearch_plan(&self, z: &[f64], e: &[f64]) -> Option<LinesearchPlan> {
        let _ = (z, e);
        None
    }

    /// Per-example sparse access for example-wise methods (SGD, SVRG,
    /// dual coordinate ascent). `None` for backends that only expose
    /// block operations (the PJRT dense backend).
    fn shard(&self) -> Option<&Shard> {
        None
    }

    /// Per-example row access abstracted over residency: resident
    /// backends derive it from [`ShardCompute::shard`], the paged
    /// backend serves rows through its block cache. Prefer this over
    /// `shard()` in method code — it is what keeps example-wise
    /// methods (CoCoA's dual ascent, the SGD warm start) working
    /// out-of-core.
    fn examples(&self) -> Option<&dyn ExampleRows> {
        self.shard().map(|s| s as &dyn ExampleRows)
    }

    /// Drain the nanoseconds kernel threads spent waiting for a disk
    /// block since the last call (the `page_stall_secs` trace column).
    /// 0 for resident backends — only the paged backend stalls on I/O.
    fn take_page_stall_ns(&self) -> u64 {
        0
    }

    /// Per-feature presence counts (TERA's per-feature averaging).
    fn feature_counts(&self) -> Vec<u32>;

    /// Drain the nanoseconds this shard's kernel blocks sat queued in
    /// the compute pool since the last call (the `queue_wait_secs`
    /// trace column). 0 for backends without a block pool.
    fn take_queue_wait_ns(&self) -> u64 {
        0
    }

    /// How many row-block partials the `*_streaming` kernels below
    /// deliver to their sink — the frame count the overlap data plane
    /// announces to its peers before the kernel runs. Backends without
    /// block streaming report 1 (the whole result as a single partial);
    /// an empty shard reports 0.
    fn stream_block_count(&self) -> usize {
        1
    }

    /// [`ShardCompute::loss_grad`] that additionally hands each row
    /// block's *partial* gradient to `sink(block_idx, partial)` the
    /// moment the block completes (in any order — the caller is
    /// responsible for in-plan-order flushing). The partials left-fold
    /// in block order to exactly the returned gradient, bit for bit —
    /// the invariant the overlap data plane's staged accumulation
    /// relies on. The default calls the plain kernel and reports the
    /// finished gradient as one block.
    fn loss_grad_streaming(
        &self,
        loss: Loss,
        w: &[f64],
        sink: &(dyn Fn(usize, &[f64]) + Sync),
    ) -> (f64, Vec<f64>, Vec<f64>) {
        let out = self.loss_grad(loss, w);
        sink(0, &out.1);
        out
    }

    /// [`ShardCompute::hvp`] with per-block partial delivery — same
    /// contract as [`ShardCompute::loss_grad_streaming`].
    fn hvp_streaming(
        &self,
        loss: Loss,
        z: &[f64],
        s: &[f64],
        sink: &(dyn Fn(usize, &[f64]) + Sync),
    ) -> Vec<f64> {
        let out = self.hvp(loss, z, s);
        sink(0, &out);
        out
    }
}

/// Native CSR backend, pre-split at construction into cache-sized
/// contiguous row blocks (see [`engine::row_blocks`]) and executed
/// block-parallel on a persistent [`ComputePool`]. Every kernel merges
/// its per-block partials in fixed block order, so the output is
/// bitwise identical for every thread count — `threads = 1` (the
/// default serial pool) is the reference ordering, not a special case.
pub struct SparseShard {
    pub data: Shard,
    /// contiguous row blocks — a pure function of the data, never of
    /// the thread count
    blocks: Vec<std::ops::Range<usize>>,
    pool: Arc<ComputePool>,
    /// kernel implementation toggle (`[worker] simd`): `true` selects
    /// the vectorizer-shaped row kernels, `false` the indexed
    /// reference. Both compute the same lane-chunked DAG
    /// ([`crate::linalg::LANES`]), so the flag can never change a bit
    /// of any result — it is pure codegen steering.
    simd: bool,
}

impl SparseShard {
    /// Serial shard (inline pool, no OS threads) — the seed behaviour.
    pub fn new(data: Shard) -> SparseShard {
        SparseShard::with_pool(data, ComputePool::serial())
    }

    /// Shard executing its blocks on `pool` (shared across the worker's
    /// shards; sized by the `[worker] threads` config key).
    pub fn with_pool(data: Shard, pool: Arc<ComputePool>) -> SparseShard {
        let blocks = engine::row_blocks(&data.x);
        SparseShard { data, blocks, pool, simd: true }
    }

    /// Explicit block-size override (tests pin the determinism contract
    /// across adversarial blockings: more blocks than threads, fewer,
    /// single-row blocks, empty rows).
    pub fn with_blocking(
        data: Shard,
        target_block_nnz: usize,
        pool: Arc<ComputePool>,
    ) -> SparseShard {
        let blocks = engine::row_blocks_with_target(&data.x, target_block_nnz);
        SparseShard { data, blocks, pool, simd: true }
    }

    /// The row blocking in effect.
    pub fn blocks(&self) -> &[std::ops::Range<usize>] {
        &self.blocks
    }

    /// The compute pool in effect.
    pub fn pool(&self) -> &Arc<ComputePool> {
        &self.pool
    }

    /// Select the kernel implementation (`[worker] simd`); results are
    /// bitwise identical either way.
    pub fn set_simd(&mut self, on: bool) {
        self.simd = on;
    }

    /// The kernel implementation in effect.
    pub fn simd(&self) -> bool {
        self.simd
    }

    /// Shared body of `loss_grad` / `loss_grad_streaming`: the fused
    /// block-parallel gradient pass, optionally handing each block's
    /// partial gradient to `sink` the moment the block finishes (before
    /// the fixed-order merge touches it).
    fn loss_grad_impl(
        &self,
        loss: Loss,
        w: &[f64],
        sink: Option<&(dyn Fn(usize, &[f64]) + Sync)>,
    ) -> (f64, Vec<f64>, Vec<f64>) {
        // Fused pass, block-parallel: each block traverses its rows
        // once while the entries are cache-hot, computing the margin,
        // the loss term and the gradient scatter together (see
        // EXPERIMENTS.md §Perf). Margins land directly in disjoint
        // slices of z; per-block (loss, gradient) partials are merged
        // in fixed block order, so bits never depend on thread count.
        let x = &self.data.x;
        let simd = self.simd;
        let mut z = vec![0.0; x.rows];
        let nb = self.blocks.len();
        if nb == 0 {
            return (0.0, vec![0.0; x.cols], z);
        }
        let y = &self.data.y;
        let c = &self.data.c;
        let blocks = &self.blocks;
        // one block's fused sweep: margins into z_part, gradient
        // scatter into g; returns the block's loss partial
        let block_pass = |b: usize, z_part: &mut [f64], g: &mut [f64]| -> f64 {
            let mut value = 0.0;
            for (k, i) in blocks[b].clone().enumerate() {
                let zi = x.row_dot_s(i, w, simd);
                z_part[k] = zi;
                let (v, d) = loss.value_dz(zi, y[i]);
                let ci = c[i];
                value += ci * v;
                let r = ci * d;
                if r != 0.0 {
                    x.row_axpy(i, r, g);
                }
            }
            value
        };
        let mut g = vec![0.0; x.cols];
        if self.pool.threads() == 1 {
            // streaming serial path: block 0 scatters into the
            // accumulator, later blocks go through ONE reusable
            // scratch buffer folded in block order — O(2m) transient
            // memory instead of O(blocks·m), bitwise identical to the
            // threaded merge (same per-coordinate left-fold order)
            let mut value = 0.0;
            let mut scratch = if nb > 1 { vec![0.0; x.cols] } else { Vec::new() };
            let z_parts = engine::split_by_ranges(&mut z, blocks);
            for (b, z_part) in z_parts.into_iter().enumerate() {
                if b == 0 {
                    value = block_pass(b, z_part, &mut g[..]);
                    if let Some(sink) = sink {
                        sink(0, &g);
                    }
                } else {
                    scratch.fill(0.0);
                    value += block_pass(b, z_part, &mut scratch[..]);
                    if let Some(sink) = sink {
                        sink(b, &scratch);
                    }
                    for (gj, sj) in g.iter_mut().zip(&scratch) {
                        *gj += *sj;
                    }
                }
            }
            return (value, g, z);
        }
        let slots: Vec<std::sync::Mutex<Option<(f64, Vec<f64>)>>> =
            (0..nb).map(|_| std::sync::Mutex::new(None)).collect();
        {
            let z_parts = engine::split_by_ranges(&mut z, blocks);
            self.pool.run_over_slices(z_parts, |b, z_part| {
                let mut gb = vec![0.0; x.cols];
                let vb = block_pass(b, z_part, &mut gb[..]);
                if let Some(sink) = sink {
                    sink(b, &gb);
                }
                *slots[b].lock().unwrap() = Some((vb, gb));
            });
        }
        let mut values = Vec::with_capacity(nb);
        let mut grads = Vec::with_capacity(nb);
        for slot in slots {
            let (vb, gb) = slot.into_inner().unwrap().unwrap();
            values.push(vb);
            grads.push(gb);
        }
        engine::merge_block_sums(&self.pool, &grads, &mut g);
        (engine::fold_block_scalars(&values), g, z)
    }

    /// Shared body of `hvp` / `hvp_streaming` — same sink contract as
    /// [`SparseShard::loss_grad_impl`].
    fn hvp_impl(
        &self,
        loss: Loss,
        z: &[f64],
        s: &[f64],
        sink: Option<&(dyn Fn(usize, &[f64]) + Sync)>,
    ) -> Vec<f64> {
        let x = &self.data.x;
        let simd = self.simd;
        debug_assert_eq!(z.len(), x.rows);
        let mut out = vec![0.0; x.cols];
        let nb = self.blocks.len();
        if nb == 0 {
            return out;
        }
        let y = &self.data.y;
        let c = &self.data.c;
        let blocks = &self.blocks;
        let block_pass = |b: usize, part: &mut [f64]| {
            let rows = blocks[b].clone();
            let mut d_block = Vec::with_capacity(rows.len());
            for i in rows.clone() {
                d_block.push(c[i] * loss.d2z(z[i], y[i]));
            }
            x.hvp_block_into(rows, &d_block, s, part, simd);
        };
        if self.pool.threads() == 1 {
            // streaming serial path — O(2m) transient memory, same
            // per-coordinate block-order fold as the threaded merge
            let mut scratch = if nb > 1 { vec![0.0; x.cols] } else { Vec::new() };
            for b in 0..nb {
                if b == 0 {
                    block_pass(b, &mut out[..]);
                    if let Some(sink) = sink {
                        sink(0, &out);
                    }
                } else {
                    scratch.fill(0.0);
                    block_pass(b, &mut scratch[..]);
                    if let Some(sink) = sink {
                        sink(b, &scratch);
                    }
                    for (oj, sj) in out.iter_mut().zip(&scratch) {
                        *oj += *sj;
                    }
                }
            }
            return out;
        }
        let parts = self.pool.map(nb, |b| {
            let mut part = vec![0.0; x.cols];
            block_pass(b, &mut part[..]);
            if let Some(sink) = sink {
                sink(b, &part);
            }
            part
        });
        engine::merge_block_sums(&self.pool, &parts, &mut out);
        out
    }
}

impl ShardCompute for SparseShard {
    fn n(&self) -> usize {
        self.data.x.rows
    }

    fn m(&self) -> usize {
        self.data.x.cols
    }

    fn nnz(&self) -> usize {
        self.data.x.nnz()
    }

    fn loss_grad(&self, loss: Loss, w: &[f64]) -> (f64, Vec<f64>, Vec<f64>) {
        self.loss_grad_impl(loss, w, None)
    }

    fn margins(&self, d: &[f64]) -> Vec<f64> {
        let x = &self.data.x;
        let simd = self.simd;
        let mut e = vec![0.0; x.rows];
        let blocks = &self.blocks;
        let parts = engine::split_by_ranges(&mut e, blocks);
        self.pool.run_over_slices(parts, |b, part| {
            x.margins_block_into(blocks[b].clone(), d, part, simd);
        });
        e
    }

    fn hvp(&self, loss: Loss, z: &[f64], s: &[f64]) -> Vec<f64> {
        self.hvp_impl(loss, z, s, None)
    }

    fn linesearch_eval(&self, loss: Loss, z: &[f64], e: &[f64], t: f64) -> (f64, f64) {
        debug_assert_eq!(z.len(), self.n());
        debug_assert_eq!(e.len(), self.n());
        let nb = self.blocks.len();
        if nb == 0 {
            return (0.0, 0.0);
        }
        let y = &self.data.y;
        let c = &self.data.c;
        let blocks = &self.blocks;
        // same lane-chunked per-block DAG as the packed plan, so the
        // two evaluation paths stay bitwise interchangeable
        let partials = self.pool.map(nb, |b| {
            let rows = blocks[b].clone();
            let lo = rows.start;
            engine::linesearch_lanes_fold(rows.len(), |k| {
                let i = lo + k;
                loss.linesearch_term(z[i], e[i], y[i], c[i], t)
            })
        });
        let phis: Vec<f64> = partials.iter().map(|&(p, _)| p).collect();
        let dphis: Vec<f64> = partials.iter().map(|&(_, d)| d).collect();
        (
            engine::fold_block_scalars(&phis),
            engine::fold_block_scalars(&dphis),
        )
    }

    fn linesearch_plan(&self, z: &[f64], e: &[f64]) -> Option<LinesearchPlan> {
        if z.len() != self.n() || e.len() != self.n() {
            return None;
        }
        Some(LinesearchPlan::build(
            &self.blocks,
            self.pool.clone(),
            self.simd,
            z,
            e,
            &self.data.y,
            &self.data.c,
        ))
    }

    fn stream_block_count(&self) -> usize {
        self.blocks.len()
    }

    fn loss_grad_streaming(
        &self,
        loss: Loss,
        w: &[f64],
        sink: &(dyn Fn(usize, &[f64]) + Sync),
    ) -> (f64, Vec<f64>, Vec<f64>) {
        self.loss_grad_impl(loss, w, Some(sink))
    }

    fn hvp_streaming(
        &self,
        loss: Loss,
        z: &[f64],
        s: &[f64],
        sink: &(dyn Fn(usize, &[f64]) + Sync),
    ) -> Vec<f64> {
        self.hvp_impl(loss, z, s, Some(sink))
    }

    fn shard(&self) -> Option<&Shard> {
        Some(&self.data)
    }

    fn feature_counts(&self) -> Vec<u32> {
        self.data.x.feature_counts()
    }

    fn take_queue_wait_ns(&self) -> u64 {
        self.pool.take_queue_wait_ns()
    }
}

/// The global objective: λ plus loss kind. Stateless helper used by
/// masters and single-machine reference solvers.
#[derive(Clone, Copy, Debug)]
pub struct Objective {
    pub lambda: f64,
    pub loss: Loss,
}

impl Objective {
    pub fn new(lambda: f64, loss: Loss) -> Objective {
        assert!(lambda > 0.0, "λ must be positive for σ-strong convexity");
        Objective { lambda, loss }
    }

    /// f(w) from an aggregated data-loss sum.
    pub fn value_from(&self, w: &[f64], loss_sum: f64) -> f64 {
        0.5 * self.lambda * linalg::dot(w, w) + loss_sum
    }

    /// g(w) from an aggregated data-gradient (in place: adds λw).
    pub fn finish_grad(&self, w: &[f64], g: &mut [f64]) {
        linalg::axpy(self.lambda, w, g);
    }

    /// Full single-machine evaluation over a set of shards (used to
    /// compute the reference optimum f* and in tests).
    pub fn eval<S: ShardCompute + ?Sized>(
        &self,
        shards: &[&S],
        w: &[f64],
    ) -> (f64, Vec<f64>) {
        let mut total = 0.0;
        let mut g = vec![0.0; w.len()];
        for s in shards {
            let (v, gp, _z) = s.loss_grad(self.loss, w);
            total += v;
            linalg::accum(&mut g, &gp);
        }
        self.finish_grad(w, &mut g);
        (self.value_from(w, total), g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn shard() -> SparseShard {
        let ds = synth::quick(64, 32, 8, 1);
        SparseShard::new(Shard::whole(&ds))
    }

    #[test]
    fn grad_matches_finite_difference() {
        let s = shard();
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let mut rng = crate::util::rng::Pcg64::new(2);
        let w: Vec<f64> = (0..32).map(|_| 0.1 * rng.normal()).collect();
        let (_, g) = obj.eval(&[&s], &w);
        let h = 1e-5;
        for j in [0usize, 5, 31] {
            let mut wp = w.clone();
            wp[j] += h;
            let mut wm = w.clone();
            wm[j] -= h;
            let (fp, _) = obj.eval(&[&s], &wp);
            let (fm, _) = obj.eval(&[&s], &wm);
            let num = (fp - fm) / (2.0 * h);
            assert!(
                (g[j] - num).abs() < 1e-4 * num.abs().max(1.0),
                "g[{j}]={} num={num}",
                g[j]
            );
        }
    }

    #[test]
    fn cached_z_matches_margins() {
        let s = shard();
        let mut rng = crate::util::rng::Pcg64::new(3);
        let w: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
        let (_, _, z) = s.loss_grad(Loss::Logistic, &w);
        assert_eq!(z, s.margins(&w));
    }

    #[test]
    fn hvp_matches_finite_difference_of_grad() {
        // for logistic (C² smooth) the GN product at z(w) equals the true
        // Hessian product of the data loss
        let s = shard();
        let mut rng = crate::util::rng::Pcg64::new(4);
        let w: Vec<f64> = (0..32).map(|_| 0.05 * rng.normal()).collect();
        let dir: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
        let (_, _, z) = s.loss_grad(Loss::Logistic, &w);
        let hv = s.hvp(Loss::Logistic, &z, &dir);
        let h = 1e-6;
        let mut wp = w.clone();
        linalg::axpy(h, &dir, &mut wp);
        let mut wm = w.clone();
        linalg::axpy(-h, &dir, &mut wm);
        let (_, gp, _) = s.loss_grad(Loss::Logistic, &wp);
        let (_, gm, _) = s.loss_grad(Loss::Logistic, &wm);
        for j in 0..32 {
            let num = (gp[j] - gm[j]) / (2.0 * h);
            assert!(
                (hv[j] - num).abs() < 1e-3 * num.abs().max(1.0),
                "j={j}: {} vs {num}",
                hv[j]
            );
        }
    }

    #[test]
    fn linesearch_eval_matches_full_evaluation() {
        let s = shard();
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let mut rng = crate::util::rng::Pcg64::new(5);
        let w: Vec<f64> = (0..32).map(|_| 0.1 * rng.normal()).collect();
        let d: Vec<f64> = (0..32).map(|_| 0.1 * rng.normal()).collect();
        let (_, _, z) = s.loss_grad(obj.loss, &w);
        let e = s.margins(&d);
        for t in [0.0, 0.25, 1.0, 3.0] {
            let (phi, _) = s.linesearch_eval(obj.loss, &z, &e, t);
            let mut wt = w.clone();
            linalg::axpy(t, &d, &mut wt);
            let want = s.loss_value(obj.loss, &wt);
            assert!((phi - want).abs() < 1e-8 * want.abs().max(1.0));
        }
    }

    #[test]
    fn linesearch_derivative_sign() {
        // moving along -g must give φ'(0) ≤ 0 on the data term when the
        // data gradient is the full gradient (λ→0 here)
        let s = shard();
        let mut rng = crate::util::rng::Pcg64::new(6);
        let w: Vec<f64> = (0..32).map(|_| 0.1 * rng.normal()).collect();
        let (_, g, z) = s.loss_grad(Loss::SquaredHinge, &w);
        let d: Vec<f64> = g.iter().map(|&v| -v).collect();
        let e = s.margins(&d);
        let (_, dphi) = s.linesearch_eval(Loss::SquaredHinge, &z, &e, 0.0);
        assert!(dphi <= 1e-12);
    }

    #[test]
    fn objective_value_and_reg() {
        let obj = Objective::new(2.0, Loss::SquaredHinge);
        let w = [3.0, 4.0];
        assert_eq!(obj.value_from(&w, 10.0), 35.0);
        let mut g = vec![1.0, 1.0];
        obj.finish_grad(&w, &mut g);
        assert_eq!(g, vec![7.0, 9.0]);
    }

    #[test]
    fn threaded_blocked_kernels_bitwise_match_serial() {
        // the engine's determinism contract: with the blocking held
        // fixed, every kernel's output is bitwise identical for any
        // thread count (the fixed-order block merge)
        let ds = synth::quick(257, 48, 8, 9);
        let data = Shard::whole(&ds);
        let serial =
            SparseShard::with_blocking(data.clone(), 64, ComputePool::serial());
        assert!(serial.blocks().len() > 4, "blocking too coarse for the test");
        let mut rng = crate::util::rng::Pcg64::new(10);
        let w: Vec<f64> = (0..48).map(|_| 0.1 * rng.normal()).collect();
        let d: Vec<f64> = (0..48).map(|_| rng.normal()).collect();
        let (v0, g0, z0) = serial.loss_grad(Loss::Logistic, &w);
        let e0 = serial.margins(&d);
        let h0 = serial.hvp(Loss::Logistic, &z0, &d);
        let (p0, q0) = serial.linesearch_eval(Loss::Logistic, &z0, &e0, 0.375);
        for threads in [2usize, 4, 8] {
            let pool = ComputePool::new(threads);
            let shard = SparseShard::with_blocking(data.clone(), 64, pool);
            let (v, g, z) = shard.loss_grad(Loss::Logistic, &w);
            assert_eq!(v.to_bits(), v0.to_bits(), "threads={threads}");
            assert!(
                g.iter().zip(&g0).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}: gradient bits diverged"
            );
            assert_eq!(z, z0, "threads={threads}");
            assert_eq!(shard.margins(&d), e0, "threads={threads}");
            let h = shard.hvp(Loss::Logistic, &z, &d);
            assert!(
                h.iter().zip(&h0).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}: hvp bits diverged"
            );
            let (p, q) = shard.linesearch_eval(Loss::Logistic, &z, &e0, 0.375);
            assert_eq!(p.to_bits(), p0.to_bits(), "threads={threads}");
            assert_eq!(q.to_bits(), q0.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn simd_toggle_never_changes_kernel_bits() {
        // the tentpole contract: simd = on|off is pure codegen steering
        let ds = synth::quick(300, 40, 9, 21);
        let data = Shard::whole(&ds);
        let mut rng = crate::util::rng::Pcg64::new(22);
        let w: Vec<f64> = (0..40).map(|_| 0.1 * rng.normal()).collect();
        let d: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        for threads in [1usize, 4] {
            let mut on =
                SparseShard::with_blocking(data.clone(), 128, ComputePool::new(threads));
            let mut off =
                SparseShard::with_blocking(data.clone(), 128, ComputePool::new(threads));
            on.set_simd(true);
            off.set_simd(false);
            let (v1, g1, z1) = on.loss_grad(Loss::Logistic, &w);
            let (v0, g0, z0) = off.loss_grad(Loss::Logistic, &w);
            assert_eq!(v1.to_bits(), v0.to_bits(), "threads={threads}");
            assert!(g1.iter().zip(&g0).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert_eq!(z1, z0);
            assert_eq!(on.margins(&d), off.margins(&d));
            let h1 = on.hvp(Loss::Logistic, &z1, &d);
            let h0 = off.hvp(Loss::Logistic, &z0, &d);
            assert!(h1.iter().zip(&h0).all(|(a, b)| a.to_bits() == b.to_bits()));
            let e = on.margins(&d);
            let p1 = on.linesearch_plan(&z1, &e).unwrap();
            let p0 = off.linesearch_plan(&z0, &e).unwrap();
            for t in [0.0, 0.5, 2.0] {
                let (a1, b1) = p1.eval(Loss::Logistic, t);
                let (a0, b0) = p0.eval(Loss::Logistic, t);
                assert_eq!(a1.to_bits(), a0.to_bits(), "t={t}");
                assert_eq!(b1.to_bits(), b0.to_bits(), "t={t}");
                let (c1, e1) = on.linesearch_eval(Loss::Logistic, &z1, &e, t);
                assert_eq!(c1.to_bits(), a1.to_bits(), "plan vs plain t={t}");
                assert_eq!(e1.to_bits(), b1.to_bits(), "plan vs plain t={t}");
            }
        }
    }

    #[test]
    fn streamed_partials_left_fold_to_the_merged_result() {
        // the overlap plane's invariant: per-block partials, copied on
        // delivery and left-folded in block order, reproduce the merged
        // gradient / Hvp bit for bit — on both engine paths
        use std::sync::Mutex;
        let ds = synth::quick(257, 48, 8, 30);
        let data = Shard::whole(&ds);
        let mut rng = crate::util::rng::Pcg64::new(31);
        let w: Vec<f64> = (0..48).map(|_| 0.1 * rng.normal()).collect();
        let s_dir: Vec<f64> = (0..48).map(|_| rng.normal()).collect();
        for threads in [1usize, 4] {
            let shard =
                SparseShard::with_blocking(data.clone(), 64, ComputePool::new(threads));
            let nb = shard.stream_block_count();
            assert!(nb > 1, "blocking too coarse for the test");
            let parts: Mutex<Vec<Option<Vec<f64>>>> = Mutex::new(vec![None; nb]);
            let sink = |b: usize, p: &[f64]| {
                parts.lock().unwrap()[b] = Some(p.to_vec());
            };
            let (_, g, z) = shard.loss_grad_streaming(Loss::SquaredHinge, &w, &sink);
            let collected = std::mem::replace(
                &mut *parts.lock().unwrap(),
                vec![None; nb],
            );
            let mut fold = collected[0].clone().unwrap();
            for p in &collected[1..] {
                for (a, b) in fold.iter_mut().zip(p.as_ref().unwrap()) {
                    *a += *b;
                }
            }
            assert!(
                fold.iter().zip(&g).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}: streamed gradient partials diverged"
            );
            let h = shard.hvp_streaming(Loss::SquaredHinge, &z, &s_dir, &sink);
            let collected = parts.into_inner().unwrap();
            let mut fold = collected[0].clone().unwrap();
            for p in &collected[1..] {
                for (a, b) in fold.iter_mut().zip(p.as_ref().unwrap()) {
                    *a += *b;
                }
            }
            assert!(
                fold.iter().zip(&h).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}: streamed hvp partials diverged"
            );
        }
    }

    #[test]
    fn linesearch_plan_matches_plain_eval_bitwise() {
        let ds = synth::quick(200, 30, 6, 12);
        let shard =
            SparseShard::with_blocking(Shard::whole(&ds), 100, ComputePool::new(3));
        let mut rng = crate::util::rng::Pcg64::new(13);
        let w: Vec<f64> = (0..30).map(|_| 0.1 * rng.normal()).collect();
        let d: Vec<f64> = (0..30).map(|_| 0.1 * rng.normal()).collect();
        let (_, _, z) = shard.loss_grad(Loss::SquaredHinge, &w);
        let e = shard.margins(&d);
        let plan = shard.linesearch_plan(&z, &e).expect("sparse backend has a plan");
        assert_eq!(plan.n(), shard.n());
        for t in [0.0, 0.25, 1.0, 3.0] {
            let (pp, pd) = plan.eval(Loss::SquaredHinge, t);
            let (wp, wd) = shard.linesearch_eval(Loss::SquaredHinge, &z, &e, t);
            assert_eq!(pp.to_bits(), wp.to_bits(), "t={t}");
            assert_eq!(pd.to_bits(), wd.to_bits(), "t={t}");
        }
        // a mismatched cache is rejected, not mis-packed
        assert!(shard.linesearch_plan(&z[1..], &e).is_none());
    }

    #[test]
    fn sharding_sums_to_whole() {
        let ds = synth::quick(100, 40, 10, 7);
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let whole = SparseShard::new(Shard::whole(&ds));
        let part = crate::data::partition::ExamplePartition::build(
            100,
            4,
            crate::data::partition::Strategy::Contiguous,
            0,
        );
        let shards: Vec<SparseShard> = (0..4)
            .map(|p| {
                SparseShard::new(Shard::from_dataset(
                    &ds,
                    &part.assignments[p],
                    &part.weights[p],
                ))
            })
            .collect();
        let mut rng = crate::util::rng::Pcg64::new(8);
        let w: Vec<f64> = (0..40).map(|_| 0.2 * rng.normal()).collect();
        let (f_whole, g_whole) = obj.eval(&[&whole], &w);
        let refs: Vec<&SparseShard> = shards.iter().collect();
        let (f_parts, g_parts) = obj.eval(&refs, &w);
        assert!((f_whole - f_parts).abs() < 1e-9 * f_whole.abs());
        for j in 0..40 {
            assert!((g_whole[j] - g_parts[j]).abs() < 1e-9);
        }
    }
}
