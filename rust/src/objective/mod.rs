//! The regularized risk functional of eq. (8) and the per-shard compute
//! backends.
//!
//! f(w) = λ/2‖w‖² + Σ_p L_p(w),   L_p(w) = Σ_{i∈I_p} c_i·l(w·x_i, y_i)
//!
//! The regularizer belongs to the *global* objective and is added once
//! by whoever aggregates (master); shards only ever compute weighted
//! data losses. [`ShardCompute`] is the backend trait: the native CSR
//! implementation lives here, the AOT/PJRT dense-block implementation
//! in [`crate::runtime`] — methods are backend-agnostic.

use crate::data::Dataset;
use crate::linalg::{self, Csr};
use crate::loss::Loss;

/// One node's slice of the data (plus per-example weights for the
/// resampling extension; all 1.0 under a plain partition).
#[derive(Clone, Debug)]
pub struct Shard {
    pub x: Csr,
    pub y: Vec<f64>,
    pub c: Vec<f64>,
}

impl Shard {
    pub fn from_dataset(ds: &Dataset, rows: &[usize], weights: &[f64]) -> Shard {
        assert_eq!(rows.len(), weights.len());
        Shard {
            x: ds.x.select_rows(rows),
            y: rows.iter().map(|&i| ds.y[i]).collect(),
            c: weights.to_vec(),
        }
    }

    pub fn whole(ds: &Dataset) -> Shard {
        Shard {
            x: ds.x.clone(),
            y: ds.y.clone(),
            c: vec![1.0; ds.n()],
        }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }
}

/// Backend-agnostic per-shard computations. All vector arguments are
/// feature-dimension unless stated otherwise.
pub trait ShardCompute: Send + Sync {
    fn n(&self) -> usize;
    fn m(&self) -> usize;
    fn nnz(&self) -> usize;

    /// (Σ c·l(z, y), Xᵀ(c·l'(z, y)), z): the gradient pass.
    /// z = X·w is returned because Algorithm 2 caches it as a by-product.
    fn loss_grad(&self, loss: Loss, w: &[f64]) -> (f64, Vec<f64>, Vec<f64>);

    /// Σ c·l(z, y) only (line-search full evaluations when margins are
    /// recomputed; prefer `linesearch_eval` on cached margins).
    fn loss_value(&self, loss: Loss, w: &[f64]) -> f64 {
        self.loss_grad(loss, w).0
    }

    /// e = X·d (one pass; Algorithm 2 step 9).
    fn margins(&self, d: &[f64]) -> Vec<f64>;

    /// Gauss–Newton Hessian-vector product at cached margins z:
    /// Hs = Xᵀ(c ⊙ l''(z, y) ⊙ (X·s)).
    fn hvp(&self, loss: Loss, z: &[f64], s: &[f64]) -> Vec<f64>;

    /// (φ(t), φ'(t)) over cached (z, e): φ(t) = Σ c·l(z + t·e, y).
    fn linesearch_eval(&self, loss: Loss, z: &[f64], e: &[f64], t: f64) -> (f64, f64);

    /// Per-example sparse access for example-wise methods (SGD, SVRG,
    /// dual coordinate ascent). `None` for backends that only expose
    /// block operations (the PJRT dense backend).
    fn shard(&self) -> Option<&Shard> {
        None
    }

    /// Per-feature presence counts (TERA's per-feature averaging).
    fn feature_counts(&self) -> Vec<u32>;
}

/// Native CSR backend.
pub struct SparseShard {
    pub data: Shard,
}

impl SparseShard {
    pub fn new(data: Shard) -> SparseShard {
        SparseShard { data }
    }
}

impl ShardCompute for SparseShard {
    fn n(&self) -> usize {
        self.data.x.rows
    }

    fn m(&self) -> usize {
        self.data.x.cols
    }

    fn nnz(&self) -> usize {
        self.data.x.nnz()
    }

    fn loss_grad(&self, loss: Loss, w: &[f64]) -> (f64, Vec<f64>, Vec<f64>) {
        // Single fused pass: each row is traversed once while its
        // entries are still cache-hot, computing the margin, the loss
        // term, and the gradient scatter together (vs the naive
        // margins → residuals → XᵀR three-pass structure; see
        // EXPERIMENTS.md §Perf for the measured ~1.8× on this path).
        let x = &self.data.x;
        let mut z = vec![0.0; x.rows];
        let mut g = vec![0.0; x.cols];
        let mut value = 0.0;
        for i in 0..x.rows {
            let zi = x.row_dot(i, w);
            z[i] = zi;
            let (v, d) = loss.value_dz(zi, self.data.y[i]);
            let ci = self.data.c[i];
            value += ci * v;
            let r = ci * d;
            if r != 0.0 {
                x.row_axpy(i, r, &mut g);
            }
        }
        (value, g, z)
    }

    fn margins(&self, d: &[f64]) -> Vec<f64> {
        let mut e = vec![0.0; self.data.x.rows];
        self.data.x.margins_into(d, &mut e);
        e
    }

    fn hvp(&self, loss: Loss, z: &[f64], s: &[f64]) -> Vec<f64> {
        let x = &self.data.x;
        debug_assert_eq!(z.len(), x.rows);
        let mut dvec = vec![0.0; x.rows];
        for i in 0..x.rows {
            dvec[i] = self.data.c[i] * loss.d2z(z[i], self.data.y[i]);
        }
        let mut out = vec![0.0; x.cols];
        x.hvp_into(&dvec, s, &mut out);
        out
    }

    fn linesearch_eval(&self, loss: Loss, z: &[f64], e: &[f64], t: f64) -> (f64, f64) {
        debug_assert_eq!(z.len(), self.n());
        debug_assert_eq!(e.len(), self.n());
        let mut phi = 0.0;
        let mut dphi = 0.0;
        for i in 0..z.len() {
            let zt = z[i] + t * e[i];
            let (v, d) = loss.value_dz(zt, self.data.y[i]);
            phi += self.data.c[i] * v;
            dphi += self.data.c[i] * d * e[i];
        }
        (phi, dphi)
    }

    fn shard(&self) -> Option<&Shard> {
        Some(&self.data)
    }

    fn feature_counts(&self) -> Vec<u32> {
        self.data.x.feature_counts()
    }
}

/// The global objective: λ plus loss kind. Stateless helper used by
/// masters and single-machine reference solvers.
#[derive(Clone, Copy, Debug)]
pub struct Objective {
    pub lambda: f64,
    pub loss: Loss,
}

impl Objective {
    pub fn new(lambda: f64, loss: Loss) -> Objective {
        assert!(lambda > 0.0, "λ must be positive for σ-strong convexity");
        Objective { lambda, loss }
    }

    /// f(w) from an aggregated data-loss sum.
    pub fn value_from(&self, w: &[f64], loss_sum: f64) -> f64 {
        0.5 * self.lambda * linalg::dot(w, w) + loss_sum
    }

    /// g(w) from an aggregated data-gradient (in place: adds λw).
    pub fn finish_grad(&self, w: &[f64], g: &mut [f64]) {
        linalg::axpy(self.lambda, w, g);
    }

    /// Full single-machine evaluation over a set of shards (used to
    /// compute the reference optimum f* and in tests).
    pub fn eval<S: ShardCompute + ?Sized>(
        &self,
        shards: &[&S],
        w: &[f64],
    ) -> (f64, Vec<f64>) {
        let mut total = 0.0;
        let mut g = vec![0.0; w.len()];
        for s in shards {
            let (v, gp, _z) = s.loss_grad(self.loss, w);
            total += v;
            linalg::accum(&mut g, &gp);
        }
        self.finish_grad(w, &mut g);
        (self.value_from(w, total), g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn shard() -> SparseShard {
        let ds = synth::quick(64, 32, 8, 1);
        SparseShard::new(Shard::whole(&ds))
    }

    #[test]
    fn grad_matches_finite_difference() {
        let s = shard();
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let mut rng = crate::util::rng::Pcg64::new(2);
        let w: Vec<f64> = (0..32).map(|_| 0.1 * rng.normal()).collect();
        let (_, g) = obj.eval(&[&s], &w);
        let h = 1e-5;
        for j in [0usize, 5, 31] {
            let mut wp = w.clone();
            wp[j] += h;
            let mut wm = w.clone();
            wm[j] -= h;
            let (fp, _) = obj.eval(&[&s], &wp);
            let (fm, _) = obj.eval(&[&s], &wm);
            let num = (fp - fm) / (2.0 * h);
            assert!(
                (g[j] - num).abs() < 1e-4 * num.abs().max(1.0),
                "g[{j}]={} num={num}",
                g[j]
            );
        }
    }

    #[test]
    fn cached_z_matches_margins() {
        let s = shard();
        let mut rng = crate::util::rng::Pcg64::new(3);
        let w: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
        let (_, _, z) = s.loss_grad(Loss::Logistic, &w);
        assert_eq!(z, s.margins(&w));
    }

    #[test]
    fn hvp_matches_finite_difference_of_grad() {
        // for logistic (C² smooth) the GN product at z(w) equals the true
        // Hessian product of the data loss
        let s = shard();
        let mut rng = crate::util::rng::Pcg64::new(4);
        let w: Vec<f64> = (0..32).map(|_| 0.05 * rng.normal()).collect();
        let dir: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
        let (_, _, z) = s.loss_grad(Loss::Logistic, &w);
        let hv = s.hvp(Loss::Logistic, &z, &dir);
        let h = 1e-6;
        let mut wp = w.clone();
        linalg::axpy(h, &dir, &mut wp);
        let mut wm = w.clone();
        linalg::axpy(-h, &dir, &mut wm);
        let (_, gp, _) = s.loss_grad(Loss::Logistic, &wp);
        let (_, gm, _) = s.loss_grad(Loss::Logistic, &wm);
        for j in 0..32 {
            let num = (gp[j] - gm[j]) / (2.0 * h);
            assert!(
                (hv[j] - num).abs() < 1e-3 * num.abs().max(1.0),
                "j={j}: {} vs {num}",
                hv[j]
            );
        }
    }

    #[test]
    fn linesearch_eval_matches_full_evaluation() {
        let s = shard();
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let mut rng = crate::util::rng::Pcg64::new(5);
        let w: Vec<f64> = (0..32).map(|_| 0.1 * rng.normal()).collect();
        let d: Vec<f64> = (0..32).map(|_| 0.1 * rng.normal()).collect();
        let (_, _, z) = s.loss_grad(obj.loss, &w);
        let e = s.margins(&d);
        for t in [0.0, 0.25, 1.0, 3.0] {
            let (phi, _) = s.linesearch_eval(obj.loss, &z, &e, t);
            let mut wt = w.clone();
            linalg::axpy(t, &d, &mut wt);
            let want = s.loss_value(obj.loss, &wt);
            assert!((phi - want).abs() < 1e-8 * want.abs().max(1.0));
        }
    }

    #[test]
    fn linesearch_derivative_sign() {
        // moving along -g must give φ'(0) ≤ 0 on the data term when the
        // data gradient is the full gradient (λ→0 here)
        let s = shard();
        let mut rng = crate::util::rng::Pcg64::new(6);
        let w: Vec<f64> = (0..32).map(|_| 0.1 * rng.normal()).collect();
        let (_, g, z) = s.loss_grad(Loss::SquaredHinge, &w);
        let d: Vec<f64> = g.iter().map(|&v| -v).collect();
        let e = s.margins(&d);
        let (_, dphi) = s.linesearch_eval(Loss::SquaredHinge, &z, &e, 0.0);
        assert!(dphi <= 1e-12);
    }

    #[test]
    fn objective_value_and_reg() {
        let obj = Objective::new(2.0, Loss::SquaredHinge);
        let w = [3.0, 4.0];
        assert_eq!(obj.value_from(&w, 10.0), 35.0);
        let mut g = vec![1.0, 1.0];
        obj.finish_grad(&w, &mut g);
        assert_eq!(g, vec![7.0, 9.0]);
    }

    #[test]
    fn sharding_sums_to_whole() {
        let ds = synth::quick(100, 40, 10, 7);
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let whole = SparseShard::new(Shard::whole(&ds));
        let part = crate::data::partition::ExamplePartition::build(
            100,
            4,
            crate::data::partition::Strategy::Contiguous,
            0,
        );
        let shards: Vec<SparseShard> = (0..4)
            .map(|p| {
                SparseShard::new(Shard::from_dataset(
                    &ds,
                    &part.assignments[p],
                    &part.weights[p],
                ))
            })
            .collect();
        let mut rng = crate::util::rng::Pcg64::new(8);
        let w: Vec<f64> = (0..40).map(|_| 0.2 * rng.normal()).collect();
        let (f_whole, g_whole) = obj.eval(&[&whole], &w);
        let refs: Vec<&SparseShard> = shards.iter().collect();
        let (f_parts, g_parts) = obj.eval(&refs, &w);
        assert!((f_whole - f_parts).abs() < 1e-9 * f_whole.abs());
        for j in 0..40 {
            assert!((g_whole[j] - g_parts[j]).abs() < 1e-9);
        }
    }
}
