//! Plain gradient descent with Armijo backtracking — the pessimistic
//! baseline inner `M`. Theorem 2's rate bound
//! δ ≤ 1 − 2α(1−β)(σ/L)²cos²θ is stated for exactly this class; the
//! ablation bench compares it against TRON to show how much the choice
//! of `M` matters in practice.

use super::{InnerOptimizer, InnerResult};
use crate::approx::LocalApprox;
use crate::linalg;

#[derive(Clone, Debug)]
pub struct GradientDescent {
    pub c1: f64,
    pub shrink: f64,
    pub grow: f64,
    pub max_backtracks: usize,
}

impl Default for GradientDescent {
    fn default() -> Self {
        GradientDescent {
            c1: 1e-4,
            shrink: 0.5,
            grow: 2.0,
            max_backtracks: 40,
        }
    }
}

impl InnerOptimizer for GradientDescent {
    fn minimize(&self, approx: &mut dyn LocalApprox, k_hat: usize) -> InnerResult {
        let mut v = approx.anchor().to_vec();
        let (mut fv, mut g) = approx.eval(&v);
        let mut t = 1.0;
        let mut iters = 0;
        for _ in 0..k_hat {
            let gg = linalg::dot(&g, &g);
            if gg <= 1e-28 {
                break;
            }
            let mut accepted = None;
            let mut step = t;
            for _ in 0..self.max_backtracks {
                let mut v_try = v.clone();
                linalg::axpy(-step, &g, &mut v_try);
                let (f_try, g_try) = approx.eval(&v_try);
                if f_try <= fv - self.c1 * step * gg {
                    accepted = Some((v_try, f_try, g_try, step));
                    break;
                }
                step *= self.shrink;
            }
            iters += 1;
            let Some((v_new, f_new, g_new, used)) = accepted else {
                break;
            };
            v = v_new;
            fv = f_new;
            g = g_new;
            // mild step growth so a too-small initial step recovers
            t = used * self.grow;
        }
        InnerResult {
            w: v,
            value: fv,
            iters,
        }
    }

    fn name(&self) -> &'static str {
        "gd"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Quadratic;
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let mut q = Quadratic::new(10, 21);
        let res = GradientDescent::default().minimize(&mut q, 300);
        assert!(res.value < 1e-8, "value {}", res.value);
    }

    #[test]
    fn descent_is_monotone_in_budget() {
        let run = |k| {
            let mut q = Quadratic::new(8, 22);
            GradientDescent::default().minimize(&mut q, k).value
        };
        assert!(run(10) <= run(2) + 1e-12);
        assert!(run(50) <= run(10) + 1e-12);
    }

    #[test]
    fn slower_than_tron_per_iteration() {
        // sanity for the ablation claim: with the same tiny budget TRON
        // reaches a much lower value than GD on an ill-conditioned problem
        let budget = 5;
        let mut q1 = Quadratic::new(25, 23);
        let gd = GradientDescent::default().minimize(&mut q1, budget).value;
        let mut q2 = Quadratic::new(25, 23);
        let tr = super::super::tron::Tron::default()
            .minimize(&mut q2, budget)
            .value;
        assert!(tr < gd, "tron {tr} vs gd {gd}");
    }
}
