//! L-BFGS inner optimizer with Armijo backtracking.
//!
//! The classic two-loop recursion on the *inverse* Hessian; glrc on
//! strongly convex objectives (Liu–Nocedal). Used both as an inner `M`
//! for f̂_p and as the outer solver of the TERA-LBFGS baseline (Fig. 1).

use super::{InnerOptimizer, InnerResult};
use crate::approx::LocalApprox;
use crate::linalg;

#[derive(Clone, Debug)]
pub struct Lbfgs {
    /// history size
    pub memory: usize,
    /// Armijo constant
    pub c1: f64,
    /// backtracking shrink factor
    pub shrink: f64,
    /// max backtracking steps per iteration
    pub max_backtracks: usize,
}

impl Default for Lbfgs {
    fn default() -> Self {
        Lbfgs {
            memory: 10,
            c1: 1e-4,
            shrink: 0.5,
            max_backtracks: 30,
        }
    }
}

struct HistoryPair {
    s: Vec<f64>,
    y: Vec<f64>,
    rho: f64,
}

/// Two-loop recursion: r = H_k · g with H₀ = γI.
fn two_loop(history: &[HistoryPair], g: &[f64], gamma: f64) -> Vec<f64> {
    let mut q = g.to_vec();
    let mut alphas = Vec::with_capacity(history.len());
    for p in history.iter().rev() {
        let a = p.rho * linalg::dot(&p.s, &q);
        linalg::axpy(-a, &p.y, &mut q);
        alphas.push(a);
    }
    linalg::scale(gamma, &mut q);
    for (p, &a) in history.iter().zip(alphas.iter().rev()) {
        let b = p.rho * linalg::dot(&p.y, &q);
        linalg::axpy(a - b, &p.s, &mut q);
    }
    q
}

impl InnerOptimizer for Lbfgs {
    fn minimize(&self, approx: &mut dyn LocalApprox, k_hat: usize) -> InnerResult {
        let mut v = approx.anchor().to_vec();
        let (mut fv, mut g) = approx.eval(&v);
        let mut history: Vec<HistoryPair> = Vec::new();
        let mut gamma = 1.0;
        let mut iters = 0;
        for _ in 0..k_hat {
            if linalg::norm(&g) <= 1e-14 {
                break;
            }
            let mut d = two_loop(&history, &g, gamma);
            linalg::scale(-1.0, &mut d);
            let gd = linalg::dot(&g, &d);
            let (d, gd) = if gd >= 0.0 {
                // numerical breakdown — fall back to steepest descent
                let d: Vec<f64> = g.iter().map(|&x| -x).collect();
                let gd = -linalg::dot(&g, &g);
                (d, gd)
            } else {
                (d, gd)
            };
            // Armijo backtracking from t = 1 (well-scaled after history)
            let mut t = 1.0;
            let mut accepted = None;
            for _ in 0..self.max_backtracks {
                let mut v_try = v.clone();
                linalg::axpy(t, &d, &mut v_try);
                let (f_try, g_try) = approx.eval(&v_try);
                if f_try <= fv + self.c1 * t * gd {
                    accepted = Some((v_try, f_try, g_try));
                    break;
                }
                t *= self.shrink;
            }
            iters += 1;
            let Some((v_new, f_new, g_new)) = accepted else {
                break; // step underflow: cannot make progress
            };
            let s = linalg::sub(&v_new, &v);
            let y = linalg::sub(&g_new, &g);
            let ys = linalg::dot(&y, &s);
            if ys > 1e-12 * linalg::dot(&s, &s).max(1e-300) {
                gamma = ys / linalg::dot(&y, &y).max(1e-300);
                history.push(HistoryPair {
                    s,
                    y,
                    rho: 1.0 / ys,
                });
                if history.len() > self.memory {
                    history.remove(0);
                }
            }
            v = v_new;
            fv = f_new;
            g = g_new;
        }
        InnerResult {
            w: v,
            value: fv,
            iters,
        }
    }

    fn name(&self) -> &'static str {
        "lbfgs"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Quadratic;
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let mut q = Quadratic::new(20, 7);
        let opt = q.optimum().to_vec();
        let res = Lbfgs::default().minimize(&mut q, 60);
        let err = linalg::dist_sq(&res.w, &opt).sqrt();
        assert!(err < 1e-5, "err {err}");
    }

    #[test]
    fn descends_monotonically_per_budget() {
        let run = |k| {
            let mut q = Quadratic::new(10, 8);
            Lbfgs::default().minimize(&mut q, k).value
        };
        let f1 = run(1);
        let f5 = run(5);
        let f20 = run(20);
        assert!(f5 <= f1 + 1e-12);
        assert!(f20 <= f5 + 1e-12);
        assert!(f20 < 1e-8);
    }

    #[test]
    fn two_loop_identity_with_empty_history() {
        let g = vec![1.0, -2.0, 3.0];
        let r = two_loop(&[], &g, 0.5);
        assert_eq!(r, vec![0.5, -1.0, 1.5]);
    }

    #[test]
    fn two_loop_solves_after_enough_pairs() {
        // With exact pairs from a quadratic, H approximates A⁻¹ on the
        // visited subspace: H(A d) ≈ d.
        let q = Quadratic::new(6, 9);
        let mut history = Vec::new();
        let mut rng = crate::util::rng::Pcg64::new(10);
        for _ in 0..6 {
            let s: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
            let y = q.apply_a(&s);
            let rho = 1.0 / linalg::dot(&y, &s);
            history.push(HistoryPair { s, y, rho });
        }
        let d: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let ad = q.apply_a(&d);
        let recovered = two_loop(&history, &ad, 1.0);
        // H is only an approximation of A⁻¹; require strong directional
        // agreement H(Ad) ≈ d rather than coordinate-exact recovery.
        let cos = linalg::dot(&recovered, &d)
            / (linalg::norm(&recovered) * linalg::norm(&d)).max(1e-300);
        assert!(cos > 0.9, "cos {cos}: {recovered:?} vs {d:?}");
    }

    #[test]
    fn zero_budget_returns_anchor() {
        let mut q = Quadratic::new(4, 11);
        let res = Lbfgs::default().minimize(&mut q, 0);
        assert_eq!(res.w, vec![0.0; 4]);
    }
}
