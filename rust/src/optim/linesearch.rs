//! Armijo–Wolfe line search over cached margins (§3.4, Lemma 1).
//!
//! Lemma 1 shows the acceptable set {t : Armijo (4) ∧ Wolfe (5)} is the
//! nonempty interval [t_β, t_α]. The search below brackets it starting
//! from t = 1 (the natural first guess since d^r comes from approximate
//! minimization), expanding forward while Wolfe fails and zooming
//! backward when Armijo fails — the forward/backward stepping +
//! bracketing procedure the paper describes. Each φ(t), φ'(t) evaluation
//! is *cheap* in the distributed setting: only the scalar t moves, the
//! nodes evaluate over cached (z_i, e_i) without touching the data
//! matrix. The caller supplies φ as a closure so the same routine runs
//! single-machine (tests) and distributed (cluster aggregation).

/// Result of a line search.
#[derive(Clone, Debug)]
pub struct LineSearchResult {
    /// the accepted step
    pub t: f64,
    /// φ(t) at the accepted step
    pub value: f64,
    /// φ evaluations consumed (each = one scalar communication round)
    pub evals: usize,
    /// whether both conditions were certified (false = fell back to the
    /// best Armijo point after hitting the iteration cap)
    pub wolfe_ok: bool,
}

/// Parameters: the paper fixes α = 1e-4, β = 0.9 (§3.4).
#[derive(Clone, Copy, Debug)]
pub struct LineSearch {
    pub alpha: f64,
    pub beta: f64,
    pub max_expand: usize,
    pub max_zoom: usize,
}

impl Default for LineSearch {
    fn default() -> Self {
        LineSearch {
            alpha: 1e-4,
            beta: 0.9,
            max_expand: 20,
            max_zoom: 30,
        }
    }
}

impl LineSearch {
    /// Find t satisfying (4) and (5).
    ///
    /// `phi(t)` must return (φ(t), φ'(t)) where φ(t) = f(w + t·d);
    /// `f0` = φ(0) and `g0d` = φ'(0) = gᵀd < 0.
    pub fn search<F: FnMut(f64) -> (f64, f64)>(
        &self,
        f0: f64,
        g0d: f64,
        mut phi: F,
    ) -> LineSearchResult {
        assert!(
            g0d < 0.0,
            "line search needs a descent direction (gᵀd = {g0d})"
        );
        let armijo = |t: f64, ft: f64| ft <= f0 + self.alpha * t * g0d;
        let wolfe = |dft: f64| dft >= self.beta * g0d;

        let mut evals = 0;
        // bracketing phase: expand t until the minimum is bracketed
        let mut lo = 0.0f64;
        let mut f_lo = f0;
        let mut t = 1.0f64;
        let mut prev_f = f0;
        for _ in 0..self.max_expand {
            let (ft, dft) = phi(t);
            evals += 1;
            if !armijo(t, ft) || ft >= prev_f {
                // overshot: minimum lies in (lo, t)
                return self.zoom(lo, f_lo, t, f0, g0d, phi, evals);
            }
            if wolfe(dft) {
                return LineSearchResult {
                    t,
                    value: ft,
                    evals,
                    wolfe_ok: true,
                };
            }
            if dft >= 0.0 {
                // derivative turned positive without violating Armijo:
                // the minimum is in (lo, t) as well
                return self.zoom(lo, f_lo, t, f0, g0d, phi, evals);
            }
            lo = t;
            f_lo = ft;
            prev_f = ft;
            t *= 2.0;
        }
        // Wolfe never certified within the expansion budget; accept the
        // last Armijo point (still a valid monotone-descent step).
        LineSearchResult {
            t: lo.max(1.0),
            value: f_lo,
            evals,
            wolfe_ok: false,
        }
    }

    /// Zoom/bisection phase on a bracketing interval (lo, hi) where lo
    /// satisfies Armijo and the minimum is inside.
    #[allow(clippy::too_many_arguments)]
    fn zoom<F: FnMut(f64) -> (f64, f64)>(
        &self,
        mut lo: f64,
        mut f_lo: f64,
        mut hi: f64,
        f0: f64,
        g0d: f64,
        mut phi: F,
        mut evals: usize,
    ) -> LineSearchResult {
        let armijo = |t: f64, ft: f64| ft <= f0 + self.alpha * t * g0d;
        let wolfe = |dft: f64| dft >= self.beta * g0d;
        let mut best = (lo, f_lo);
        for _ in 0..self.max_zoom {
            let t = 0.5 * (lo + hi);
            let (ft, dft) = phi(t);
            evals += 1;
            if !armijo(t, ft) || ft >= f_lo {
                hi = t;
            } else {
                if wolfe(dft) {
                    return LineSearchResult {
                        t,
                        value: ft,
                        evals,
                        wolfe_ok: true,
                    };
                }
                if ft < best.1 {
                    best = (t, ft);
                }
                if dft * (hi - lo) >= 0.0 {
                    hi = lo;
                }
                lo = t;
                f_lo = ft;
            }
            if (hi - lo).abs() < 1e-14 {
                break;
            }
        }
        // interval collapsed: return the best Armijo point seen
        let (t, value) = if best.0 > 0.0 { best } else { (lo.max(1e-12), f_lo) };
        LineSearchResult {
            t,
            value,
            evals,
            wolfe_ok: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// φ from a 1-D strongly convex quadratic: f(w+td) along d.
    fn quad_phi(t: f64) -> (f64, f64) {
        // f(t) = (t-3)², f' = 2(t-3); f0 = 9, g0d = -6
        ((t - 3.0) * (t - 3.0), 2.0 * (t - 3.0))
    }

    #[test]
    fn finds_admissible_step_on_quadratic() {
        let ls = LineSearch::default();
        let res = ls.search(9.0, -6.0, quad_phi);
        assert!(res.wolfe_ok);
        // Armijo: (t-3)² ≤ 9 − 1e-4·6t; Wolfe: 2(t−3) ≥ −5.4
        assert!(res.value <= 9.0 + 1e-4 * res.t * -6.0);
        assert!(2.0 * (res.t - 3.0) >= 0.9 * -6.0);
        assert!(res.t > 0.0);
    }

    #[test]
    fn immediate_accept_when_t1_is_good() {
        // minimum near t = 1: φ(t) = (t−1)², φ'(1) = 0 satisfies Wolfe
        let ls = LineSearch::default();
        let res = ls.search(1.0, -2.0, |t| ((t - 1.0) * (t - 1.0), 2.0 * (t - 1.0)));
        assert_eq!(res.evals, 1);
        assert!(res.wolfe_ok);
        assert_eq!(res.t, 1.0);
    }

    #[test]
    fn backtracks_when_t1_overshoots() {
        // minimum at t = 0.01 → t = 1 violates Armijo badly.
        // φ(t) = 100(t−0.01)²: f0 = 0.01, φ'(0) = −2.
        let ls = LineSearch::default();
        let res = ls.search(0.01, -2.0, |t| {
            let d = t - 0.01;
            (100.0 * d * d, 200.0 * d)
        });
        assert!(res.t < 0.6, "t = {}", res.t);
        assert!(res.value <= 0.01 + ls.alpha * res.t * -2.0);
    }

    #[test]
    fn expands_when_minimum_is_far() {
        // minimum at t = 40. With β = 0.9 the Wolfe condition already
        // holds at t = 4 (φ'(4) = −72 = β·φ'(0)), the first expansion
        // point inside [t_β, t_α] — expansion must reach at least there.
        let ls = LineSearch::default();
        let res = ls.search(1600.0, -80.0, |t| {
            let d = t - 40.0;
            (d * d, 2.0 * d)
        });
        assert!(res.t >= 4.0, "t = {}", res.t);
        assert!(res.wolfe_ok);
    }

    #[test]
    fn wolfe_interval_matches_lemma1() {
        // Lemma 1: the admissible set is [t_β, t_α]. For φ(t) = (t−3)²,
        // f0 = 9, g0d = −6, α = 1e-4, β = 0.9:
        //   t_β: 2(t−3) = −5.4 → t_β = 0.3
        //   t_α: (t−3)² = 9 − 6e-4·t → t_α ≈ 5.9994
        let ls = LineSearch::default();
        let res = ls.search(9.0, -6.0, quad_phi);
        assert!(res.t >= 0.3 - 1e-9 && res.t <= 6.0, "t = {}", res.t);
    }

    #[test]
    #[should_panic]
    fn rejects_ascent_direction() {
        LineSearch::default().search(1.0, 0.5, quad_phi);
    }

    #[test]
    fn eval_count_is_small() {
        // the paper's point: line search is cheap — single digits of
        // scalar rounds even for awkward curvatures
        let ls = LineSearch::default();
        for &tmin in &[0.03, 0.3, 1.0, 7.0, 29.0] {
            let res = ls.search(tmin * tmin, -2.0 * tmin, |t| {
                let d = t - tmin;
                (d * d, 2.0 * d)
            });
            assert!(res.evals <= 15, "tmin={tmin}: {} evals", res.evals);
        }
    }
}
