//! Inner optimizers `M` and the distributed line search.
//!
//! Theorem 4 only needs `M` to have global linear rate of convergence on
//! the σ-strongly-convex f̂_p; Lemma 3 then guarantees a *constant*
//! number k̂ of iterations suffices for the sufficient-angle condition.
//! We provide the choices §3.4 lists:
//!
//! * [`tron::Tron`] — Trust Region Newton (Lin–Weng–Keerthi 2008), the
//!   paper's default `M` (and the TERA outer solver),
//! * [`lbfgs::Lbfgs`] — limited-memory BFGS with Armijo backtracking,
//! * [`gd::GradientDescent`] — plain gradient descent w/ backtracking
//!   (the pessimistic baseline covered by Theorem 2's rate bound),
//! * [`sgd::Sgd`] / [`sgd::Svrg`] — example-wise methods for the
//!   parallel-SGD instantiation of §3.5 (SVRG update ≡ eq. (20)),
//! * [`linesearch`] — the Armijo–Wolfe search over cached margins
//!   (Algorithm 2 step 10, Lemma 1).

pub mod gd;
pub mod lbfgs;
pub mod linesearch;
pub mod sgd;
pub mod tron;

use crate::approx::LocalApprox;

/// Outcome of an inner minimization.
#[derive(Clone, Debug)]
pub struct InnerResult {
    /// the approximate minimizer w_p
    pub w: Vec<f64>,
    /// f̂_p(w_p)
    pub value: f64,
    /// iterations actually performed
    pub iters: usize,
}

/// An inner optimizer `M` for f̂_p: run `k_hat` iterations from the
/// anchor w^r (Algorithm 2 steps 4–7).
pub trait InnerOptimizer: Send + Sync {
    fn minimize(&self, approx: &mut dyn LocalApprox, k_hat: usize) -> InnerResult;
    fn name(&self) -> &'static str;
}

/// Inner optimizer selector (config-file spelling).
pub fn by_name(name: &str) -> Option<Box<dyn InnerOptimizer>> {
    match name {
        "tron" => Some(Box::new(tron::Tron::default())),
        "lbfgs" => Some(Box::new(lbfgs::Lbfgs::default())),
        "gd" => Some(Box::new(gd::GradientDescent::default())),
        "sgd" => Some(Box::new(sgd::Sgd::default())),
        "svrg" => Some(Box::new(sgd::Svrg::default())),
        _ => None,
    }
}

/// [`by_name`] with FADL's carried-over TRON trust radius applied (the
/// adaptive inner region of Algorithm 2; only TRON consumes it). Used
/// by the worker-side inner solve so the in-process and TCP transports
/// build the identical optimizer.
pub fn build_inner(name: &str, trust_radius: Option<f64>) -> Option<Box<dyn InnerOptimizer>> {
    if name == "tron" {
        return Some(Box::new(tron::Tron {
            init_radius: trust_radius,
            ..Default::default()
        }));
    }
    by_name(name)
}

#[cfg(test)]
pub(crate) mod testutil {
    //! A synthetic strongly-convex quadratic exposed through the
    //! [`LocalApprox`] interface so every optimizer can be tested
    //! against a problem with a known minimizer.
    use crate::approx::LocalApprox;
    use crate::linalg;

    /// f(v) = ½(v−c)ᵀA(v−c), A = diag + rank-1, SPD.
    pub struct Quadratic {
        pub diag: Vec<f64>,
        pub rank1: Vec<f64>,
        pub center: Vec<f64>,
        pub anchor: Vec<f64>,
        pub evals: usize,
    }

    impl Quadratic {
        pub fn new(dim: usize, seed: u64) -> Quadratic {
            let mut rng = crate::util::rng::Pcg64::new(seed);
            Quadratic {
                diag: (0..dim).map(|_| 0.5 + rng.f64() * 4.0).collect(),
                rank1: (0..dim).map(|_| rng.normal() * 0.3).collect(),
                center: (0..dim).map(|_| rng.normal()).collect(),
                anchor: vec![0.0; dim],
                evals: 0,
            }
        }

        pub fn apply_a(&self, v: &[f64]) -> Vec<f64> {
            let rv = linalg::dot(&self.rank1, v);
            (0..v.len())
                .map(|j| self.diag[j] * v[j] + self.rank1[j] * rv)
                .collect()
        }

        pub fn optimum(&self) -> &[f64] {
            &self.center
        }
    }

    impl LocalApprox for Quadratic {
        fn m(&self) -> usize {
            self.center.len()
        }

        fn eval(&mut self, v: &[f64]) -> (f64, Vec<f64>) {
            self.evals += 1;
            let d = linalg::sub(v, &self.center);
            let ad = self.apply_a(&d);
            (0.5 * linalg::dot(&d, &ad), ad)
        }

        fn hvp(&self, s: &[f64]) -> Vec<f64> {
            self.apply_a(s)
        }

        fn passes(&self) -> f64 {
            self.evals as f64
        }

        fn anchor(&self) -> &[f64] {
            &self.anchor
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_covers_all() {
        for n in ["tron", "lbfgs", "gd", "sgd", "svrg"] {
            assert!(by_name(n).is_some(), "{n}");
            assert_eq!(by_name(n).unwrap().name(), n);
        }
        assert!(by_name("adam").is_none());
    }
}
