//! Example-wise inner optimizers: plain SGD and SVRG (paper §3.5).
//!
//! Using SGD as `M` on the Linear approximation (eq. (11)) makes the
//! whole distributed method a *parallel SGD with strong convergence*
//! (the paper's Q3). The per-example stochastic gradients are
//!
//!   SGD  (on f̂_p):  λv + n_p·c_i·l'(v·x_i, y_i)·x_i + (∇L − ∇L_p)(w^r)
//!   SVRG (eq. 20):   n_p·c_i·(l'(v·x_i) − l'(w^r·x_i))·x_i + g^r
//!
//! Both are unbiased estimates of ∇f̂_p(v); the SVRG form is exactly the
//! variance-reduced update of Johnson–Zhang 2013 (the paper derives it
//! from the functional-approximation viewpoint instead). One "iteration"
//! of `M` = one epoch over the shard.

use super::{InnerOptimizer, InnerResult};
use crate::approx::LocalApprox;
use crate::linalg;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct Sgd {
    /// step size; 0.0 = auto (1 / (n_p·R²·curv + λ), the safe bound)
    pub eta: f64,
    pub seed: u64,
}

impl Default for Sgd {
    fn default() -> Self {
        Sgd { eta: 0.0, seed: 12345 }
    }
}

#[derive(Clone, Debug)]
pub struct Svrg {
    pub eta: f64,
    pub seed: u64,
}

impl Default for Svrg {
    fn default() -> Self {
        Svrg { eta: 0.0, seed: 12345 }
    }
}

fn auto_eta(view: &crate::approx::StochasticView<'_>, requested: f64) -> f64 {
    if requested > 0.0 {
        return requested;
    }
    let shard = view.shard_data;
    let n = shard.n() as f64;
    let mut max_row_sq: f64 = 0.0;
    for i in 0..shard.n() {
        max_row_sq = max_row_sq.max(shard.x.row_norm_sq(i) * shard.c[i]);
    }
    let lip = n * max_row_sq * view.loss.curvature_bound() + view.lambda;
    // Johnson–Zhang recommend η ≤ 1/(4·L_max) for SVRG stability; the
    // same bound keeps plain SGD on f̂_p non-oscillatory.
    0.25 / lip.max(1e-12)
}

fn epoch_order(n: usize, rng: &mut Pcg64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx
}

impl InnerOptimizer for Sgd {
    fn minimize(&self, approx: &mut dyn LocalApprox, k_hat: usize) -> InnerResult {
        let Some(view) = approx.stochastic() else {
            // backend without per-example access: degrade to GD
            return super::gd::GradientDescent::default().minimize(approx, k_hat);
        };
        let eta = auto_eta(&view, self.eta);
        let n = view.shard_data.n();
        let lambda = view.lambda;
        let loss = view.loss;
        // lin = (∇L − ∇L_p)(w^r), dense and constant over the epoch
        let mut lin = view.full_grad.to_vec();
        linalg::axpy(-lambda, view.anchor, &mut lin);
        linalg::axpy(-1.0, view.local_grad, &mut lin);
        let shard = view.shard_data;
        let x = shard.x.clone();
        let y = shard.y.clone();
        let c = shard.c.clone();
        let mut v = view.anchor.to_vec();
        drop(view);

        let mut rng = Pcg64::new(self.seed);
        let mut iters = 0;
        for _ in 0..k_hat {
            for &i in &epoch_order(n, &mut rng) {
                let z = x.row_dot(i, &v);
                let r = n as f64 * c[i] * loss.dz(z, y[i]);
                // v ← v − η(λv + r·x_i + lin)
                linalg::scale(1.0 - eta * lambda, &mut v);
                x.row_axpy(i, -eta * r, &mut v);
                linalg::axpy(-eta, &lin, &mut v);
            }
            iters += 1;
        }
        let (value, _g) = approx.eval(&v);
        InnerResult {
            w: v,
            value,
            iters,
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

impl InnerOptimizer for Svrg {
    fn minimize(&self, approx: &mut dyn LocalApprox, k_hat: usize) -> InnerResult {
        let Some(view) = approx.stochastic() else {
            return super::gd::GradientDescent::default().minimize(approx, k_hat);
        };
        let eta = auto_eta(&view, self.eta);
        let n = view.shard_data.n();
        let loss = view.loss;
        let full_grad = view.full_grad.to_vec();
        let anchor_margins = view.anchor_margins.to_vec();
        let shard = view.shard_data;
        let x = shard.x.clone();
        let y = shard.y.clone();
        let c = shard.c.clone();
        let mut v = view.anchor.to_vec();
        drop(view);

        let lambda = {
            // ψ_i(w) = n_p·c_i·l_i(w) + λ/2‖w‖², so the variance-reduced
            // difference carries a λ(w − w^r) term as well (eq. (19)).
            let view2 = approx.stochastic().unwrap();
            view2.lambda
        };
        let anchor = approx.anchor().to_vec();
        let mut rng = Pcg64::new(self.seed);
        let mut iters = 0;
        for _ in 0..k_hat {
            for &i in &epoch_order(n, &mut rng) {
                let z = x.row_dot(i, &v);
                // eq. (20): w ← w − η(∇ψ_i(w) − ∇ψ_i(w^r) + g^r)
                let dr = n as f64 * c[i] * (loss.dz(z, y[i]) - loss.dz(anchor_margins[i], y[i]));
                x.row_axpy(i, -eta * dr, &mut v);
                for j in 0..v.len() {
                    v[j] -= eta * (lambda * (v[j] - anchor[j]) + full_grad[j]);
                }
            }
            iters += 1;
        }
        let (value, _g) = approx.eval(&v);
        InnerResult {
            w: v,
            value,
            iters,
        }
    }

    fn name(&self) -> &'static str {
        "svrg"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{build, ApproxContext, ApproxKind};
    use crate::data::synth;
    use crate::loss::Loss;
    use crate::objective::{Objective, Shard, ShardCompute, SparseShard};

    struct Fx {
        shard: SparseShard,
        obj: Objective,
        w: Vec<f64>,
    }

    fn fixture() -> Fx {
        fixture_with_lambda(1e-2)
    }

    fn fixture_with_lambda(lambda: f64) -> Fx {
        let ds = synth::quick(60, 20, 6, 31);
        let shard = SparseShard::new(Shard::whole(&ds));
        let obj = Objective::new(lambda, Loss::SquaredHinge);
        let mut rng = crate::util::rng::Pcg64::new(1);
        let w: Vec<f64> = (0..20).map(|_| 0.1 * rng.normal()).collect();
        Fx { shard, obj, w }
    }

    fn linear_ctx(fx: &Fx) -> ApproxContext<'_> {
        let (_f, g) = fx.obj.eval(&[&fx.shard], &fx.w);
        let (_, lg, z) = fx.shard.loss_grad(fx.obj.loss, &fx.w);
        ApproxContext {
            shard: &fx.shard,
            loss: fx.obj.loss,
            lambda: fx.obj.lambda,
            p_nodes: 1.0,
            anchor: fx.w.clone(),
            full_grad: g,
            local_grad: lg,
            anchor_margins: z,
        }
    }

    #[test]
    fn sgd_decreases_objective() {
        let fx = fixture();
        let mut approx = build(ApproxKind::Linear, linear_ctx(&fx), None);
        let (f0, _) = approx.eval(&fx.w);
        let res = Sgd::default().minimize(approx.as_mut(), 3);
        assert!(res.value < f0, "{} !< {f0}", res.value);
    }

    #[test]
    fn svrg_shrinks_gradient_on_well_conditioned_problem() {
        // SVRG's per-epoch rate degrades with the condition number
        // κ = L/σ (Johnson–Zhang Thm 1), so certify linear progress on a
        // well-conditioned instance (λ = 1).
        let fx = fixture_with_lambda(1.0);
        let mut a1 = build(ApproxKind::Linear, linear_ctx(&fx), None);
        let (f0, g_start) = a1.eval(&fx.w);
        let svrg = Svrg::default().minimize(a1.as_mut(), 10);
        assert!(svrg.value < f0);
        // with P = 1 the linear f̂ is the true f, so SVRG should approach
        // the true optimum: gradient norm shrinks materially
        let (_, g_end) = a1.eval(&svrg.w);
        assert!(
            crate::linalg::norm(&g_end) < 0.8 * crate::linalg::norm(&g_start),
            "{} vs {}",
            crate::linalg::norm(&g_end),
            crate::linalg::norm(&g_start)
        );
    }

    #[test]
    fn svrg_fixed_point_is_anchor_at_optimum() {
        // If w^r is already the minimizer, g^r = 0 and every SVRG update
        // starting from v = w^r is exactly zero → w stays put.
        let fx = fixture();
        // get near-optimal w via TRON on the true objective
        let opt = {
            let mut approx = build(ApproxKind::Linear, linear_ctx(&fx), None);
            // k_hat is a CG-product budget — give enough for a deep solve
            super::super::tron::Tron::default().minimize(approx.as_mut(), 400)
        };
        let fx2 = Fx {
            shard: fx.shard,
            obj: fx.obj,
            w: opt.w.clone(),
        };
        let mut a2 = build(ApproxKind::Linear, linear_ctx(&fx2), None);
        let res = Svrg::default().minimize(a2.as_mut(), 2);
        let drift = crate::linalg::dist_sq(&res.w, &opt.w).sqrt();
        assert!(drift < 1e-2, "drift {drift} (w* is only approximate)");
    }

    #[test]
    fn deterministic_given_seed() {
        let fx = fixture();
        let mut a = build(ApproxKind::Linear, linear_ctx(&fx), None);
        let r1 = Sgd::default().minimize(a.as_mut(), 2);
        let mut b = build(ApproxKind::Linear, linear_ctx(&fx), None);
        let r2 = Sgd::default().minimize(b.as_mut(), 2);
        assert_eq!(r1.w, r2.w);
    }

    #[test]
    fn falls_back_without_stochastic_view() {
        let mut q = super::super::testutil::Quadratic::new(6, 3);
        let res = Sgd::default().minimize(&mut q, 50);
        assert!(res.value < 1e-6, "fallback GD failed: {}", res.value);
    }
}
