//! TRON — Trust Region Newton method (Lin, Weng, Keerthi 2008).
//!
//! The paper's default inner optimizer `M` and also the outer solver of
//! TERA-TRON (§4.3). Each iteration evaluates (f, g) at the current
//! point, runs Steihaug conjugate gradient on the quadratic model
//! ½sᵀHs + gᵀs inside the trust region, and accepts/rejects the step by
//! the actual-vs-predicted reduction ratio. On σ-strongly-convex
//! objectives TRON has global linear rate, which is exactly what
//! Lemma 3 requires of `M`.

use super::{InnerOptimizer, InnerResult};
use crate::approx::LocalApprox;
use crate::linalg;

/// TRON parameters (the η/σ update constants of Lin–Weng–Keerthi).
#[derive(Clone, Debug)]
pub struct Tron {
    /// CG iterations cap per Newton step (the k̂ driver in Appendix A is
    /// the *total* CG products; this bounds each inner solve)
    pub max_cg: usize,
    /// CG relative residual tolerance
    pub cg_tol: f64,
    /// step acceptance threshold η₀
    pub eta0: f64,
    /// good-step threshold η₂ (expand region beyond it)
    pub eta2: f64,
    /// initial trust radius as a multiple of ‖g‖
    pub init_radius_scale: f64,
    /// explicit initial trust radius (overrides the ‖g‖ scaling).
    /// FADL threads the previous outer iteration's accepted step length
    /// through this: with a piecewise-quadratic loss the local model's
    /// trust region is exactly the region where the anchor's active set
    /// is representative, which the last line search measured.
    pub init_radius: Option<f64>,
}

impl Default for Tron {
    fn default() -> Self {
        Tron {
            max_cg: 20,
            cg_tol: 0.1,
            eta0: 1e-4,
            eta2: 0.75,
            init_radius_scale: 1.0,
            init_radius: None,
        }
    }
}

/// Steihaug-CG: approximately minimize ½sᵀHs + gᵀs s.t. ‖s‖ ≤ Δ.
/// Returns (s, hit_boundary, cg_iters).
fn steihaug(
    approx: &dyn LocalApprox,
    g: &[f64],
    delta: f64,
    max_cg: usize,
    tol: f64,
) -> (Vec<f64>, bool, usize) {
    let m = g.len();
    let mut s = vec![0.0; m];
    let mut r: Vec<f64> = g.iter().map(|&x| -x).collect(); // r = -g - Hs (s=0)
    let mut d = r.clone();
    let r0_norm = linalg::norm(&r);
    if r0_norm == 0.0 {
        return (s, false, 0);
    }
    let mut rr = linalg::dot(&r, &r);
    for it in 0..max_cg {
        let hd = approx.hvp(&d);
        let dhd = linalg::dot(&d, &hd);
        if dhd <= 0.0 {
            // nonconvex direction cannot happen for our f̂_p (σ-convex),
            // but guard anyway: walk to the boundary.
            let tau = boundary_tau(&s, &d, delta);
            linalg::axpy(tau, &d, &mut s);
            return (s, true, it + 1);
        }
        let alpha = rr / dhd;
        // would the step leave the region?
        let mut s_next = s.clone();
        linalg::axpy(alpha, &d, &mut s_next);
        if linalg::norm(&s_next) >= delta {
            let tau = boundary_tau(&s, &d, delta);
            linalg::axpy(tau, &d, &mut s);
            return (s, true, it + 1);
        }
        s = s_next;
        linalg::axpy(-alpha, &hd, &mut r);
        let rr_new = linalg::dot(&r, &r);
        if rr_new.sqrt() <= tol * r0_norm {
            return (s, false, it + 1);
        }
        let beta = rr_new / rr;
        rr = rr_new;
        linalg::axpby(1.0, &r, beta, &mut d);
    }
    (s, false, max_cg)
}

/// τ ≥ 0 with ‖s + τd‖ = Δ.
fn boundary_tau(s: &[f64], d: &[f64], delta: f64) -> f64 {
    let dd = linalg::dot(d, d);
    let sd = linalg::dot(s, d);
    let ss = linalg::dot(s, s);
    let disc = (sd * sd + dd * (delta * delta - ss)).max(0.0);
    (-sd + disc.sqrt()) / dd.max(1e-300)
}

impl InnerOptimizer for Tron {
    /// `k_hat` is the **total CG-product budget**, matching the paper's
    /// Appendix-A definition ("k̂ is the average number of conjugate
    /// gradient iterations required per outer iteration", typically
    /// 5–20). This matters beyond cost accounting: truncated CG only
    /// moves within the Krylov space of the local Hessian, which
    /// regularizes the f̂_p minimizer in directions where the node has
    /// no curvature (features unseen in its shard) — the exact
    /// minimizer would move those coordinates by −g_j/λ, a direction
    /// that makes the combined d^r nearly orthogonal to −g.
    fn minimize(&self, approx: &mut dyn LocalApprox, k_hat: usize) -> InnerResult {
        let mut v = approx.anchor().to_vec();
        let (mut fv, mut g) = approx.eval(&v);
        let mut radius = self
            .init_radius
            .unwrap_or_else(|| self.init_radius_scale * linalg::norm(&g))
            .max(1e-12);
        let mut iters = 0;
        let mut cg_budget = k_hat;
        while cg_budget > 0 {
            let gnorm = linalg::norm(&g);
            if gnorm <= 1e-14 {
                break;
            }
            let cg_cap = self.max_cg.min(cg_budget);
            let (s, hit_boundary, cg_used) =
                steihaug(approx, &g, radius, cg_cap, self.cg_tol);
            cg_budget -= cg_used.max(1).min(cg_budget);
            let hs = approx.hvp(&s);
            let predicted = -(linalg::dot(&g, &s) + 0.5 * linalg::dot(&s, &hs));
            let mut v_try = v.clone();
            linalg::accum(&mut v_try, &s);
            let (f_try, g_try) = approx.eval(&v_try);
            let actual = fv - f_try;
            let rho = if predicted.abs() < 1e-300 {
                1.0
            } else {
                actual / predicted
            };
            iters += cg_used.max(1);
            if rho > self.eta0 {
                v = v_try;
                fv = f_try;
                g = g_try;
                if rho > self.eta2 && hit_boundary {
                    radius *= 2.0;
                }
            } else {
                radius *= 0.25;
            }
            if rho <= self.eta0 && radius < 1e-16 {
                break;
            }
        }
        InnerResult {
            w: v,
            value: fv,
            iters,
        }
    }

    fn name(&self) -> &'static str {
        "tron"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Quadratic;
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        let mut q = Quadratic::new(20, 1);
        let opt = q.optimum().to_vec();
        let res = Tron::default().minimize(&mut q, 30);
        let err = linalg::dist_sq(&res.w, &opt).sqrt();
        assert!(err < 1e-6, "err {err}");
        assert!(res.value < 1e-10, "value {}", res.value);
    }

    #[test]
    fn monotone_descent() {
        let mut q = Quadratic::new(12, 2);
        let (f0, _) = q.eval(&vec![0.0; 12]);
        let mut prev = f0;
        for k in 1..=6 {
            let mut q2 = Quadratic::new(12, 2);
            let res = Tron::default().minimize(&mut q2, k);
            assert!(
                res.value <= prev + 1e-12,
                "k={k}: {} > {prev}",
                res.value
            );
            prev = res.value;
        }
    }

    #[test]
    fn linear_rate_on_quadratic() {
        // glrc check: value must shrink geometrically with k̂
        let run = |k| {
            let mut q = Quadratic::new(15, 3);
            Tron::default().minimize(&mut q, k).value
        };
        let f2 = run(2);
        let f4 = run(4);
        let f8 = run(8);
        assert!(f4 < 0.5 * f2, "{f4} vs {f2}");
        assert!(f8 < 0.5 * f4 || f8 < 1e-12, "{f8} vs {f4}");
    }

    #[test]
    fn zero_iterations_returns_anchor() {
        let mut q = Quadratic::new(5, 4);
        let res = Tron::default().minimize(&mut q, 0);
        assert_eq!(res.w, vec![0.0; 5]);
        assert_eq!(res.iters, 0);
    }

    #[test]
    fn steihaug_respects_radius() {
        let q = Quadratic::new(10, 5);
        let g: Vec<f64> = (0..10).map(|i| (i as f64 + 1.0) * 0.3).collect();
        for &delta in &[1e-3, 0.1, 1.0] {
            let (s, _hit, _) = steihaug(&q, &g, delta, 50, 1e-10);
            assert!(linalg::norm(&s) <= delta * (1.0 + 1e-9));
        }
    }

    #[test]
    fn boundary_tau_is_exact() {
        let s = vec![0.5, 0.0];
        let d = vec![1.0, 0.0];
        let tau = boundary_tau(&s, &d, 2.0);
        assert!((tau - 1.5).abs() < 1e-12);
    }

    #[test]
    fn already_optimal_stays_put() {
        let mut q = Quadratic::new(8, 6);
        // move anchor to the optimum
        q.anchor = q.center.clone();
        let res = Tron::default().minimize(&mut q, 10);
        let err = linalg::dist_sq(&res.w, &q.center).sqrt();
        assert!(err < 1e-9);
    }
}
