//! Dense-block shard backend: [`crate::objective::ShardCompute`] served
//! by the AOT/PJRT runtime.
//!
//! The shard's rows are materialized into fixed (B, M) f32 blocks (B =
//! the artifact batch size); the final ragged block is padded with zero
//! rows carrying weight c = 0, which the Layer-2 model treats as
//! perfectly neutral (see `python/tests/test_model.py::
//! test_padding_rows_are_neutral`). Every block operation is one
//! executable call; accumulation across blocks happens in f64 on the
//! Rust side to keep the shard-level sums well conditioned.

use std::sync::Arc;

use super::pjrt::AotRuntime;
use crate::loss::Loss;
use crate::objective::{Shard, ShardCompute};

/// A dense example shard whose compute runs through the AOT artifacts.
pub struct DenseBlockShard {
    runtime: Arc<AotRuntime>,
    /// (B·M) f32 per block, row-major
    blocks: Vec<Vec<f32>>,
    /// (B) labels per block (+1 padding rows)
    ys: Vec<Vec<f32>>,
    /// (B) weights per block (0 padding rows)
    cs: Vec<Vec<f32>>,
    /// true (unpadded) number of examples
    n: usize,
    nnz: usize,
    feature_counts: Vec<u32>,
}

impl DenseBlockShard {
    /// Build from a CSR shard. Requires `shard.x.cols == runtime.features`.
    pub fn new(runtime: Arc<AotRuntime>, shard: &Shard) -> DenseBlockShard {
        let b = runtime.batch;
        let m = runtime.features;
        assert_eq!(
            shard.x.cols, m,
            "shard has {} features but artifacts were lowered for {m}",
            shard.x.cols
        );
        let n = shard.x.rows;
        let nblocks = n.div_ceil(b).max(1);
        let mut blocks = Vec::with_capacity(nblocks);
        let mut ys = Vec::with_capacity(nblocks);
        let mut cs = Vec::with_capacity(nblocks);
        let mut rowbuf = vec![0.0f32; m];
        for blk in 0..nblocks {
            let mut x = vec![0.0f32; b * m];
            let mut y = vec![1.0f32; b];
            let mut c = vec![0.0f32; b];
            for r in 0..b {
                let i = blk * b + r;
                if i >= n {
                    break;
                }
                shard.x.densify_row(i, &mut rowbuf);
                x[r * m..(r + 1) * m].copy_from_slice(&rowbuf);
                y[r] = shard.y[i] as f32;
                c[r] = shard.c[i] as f32;
            }
            blocks.push(x);
            ys.push(y);
            cs.push(c);
        }
        DenseBlockShard {
            runtime,
            blocks,
            ys,
            cs,
            n,
            nnz: shard.x.nnz(),
            feature_counts: shard.x.feature_counts(),
        }
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    fn w32(&self, w: &[f64]) -> Vec<f32> {
        w.iter().map(|&x| x as f32).collect()
    }

    fn check_loss(&self, loss: Loss) {
        assert_eq!(
            loss, self.runtime.loss,
            "artifacts were lowered for {:?}",
            self.runtime.loss
        );
    }
}

impl ShardCompute for DenseBlockShard {
    fn n(&self) -> usize {
        self.n
    }

    fn m(&self) -> usize {
        self.runtime.features
    }

    fn nnz(&self) -> usize {
        self.nnz
    }

    fn loss_grad(&self, loss: Loss, w: &[f64]) -> (f64, Vec<f64>, Vec<f64>) {
        self.check_loss(loss);
        let wf = self.w32(w);
        let mut total = 0.0f64;
        let mut grad = vec![0.0f64; w.len()];
        let mut margins = Vec::with_capacity(self.n);
        for blk in 0..self.blocks.len() {
            let (l, g, z) = self
                .runtime
                .obj_grad(&self.blocks[blk], &self.ys[blk], &self.cs[blk], &wf)
                .expect("obj_grad artifact execution failed");
            total += l as f64;
            for (acc, &gi) in grad.iter_mut().zip(&g) {
                *acc += gi as f64;
            }
            let keep = (self.n - blk * self.runtime.batch).min(self.runtime.batch);
            margins.extend(z[..keep].iter().map(|&v| v as f64));
        }
        (total, grad, margins)
    }

    fn margins(&self, d: &[f64]) -> Vec<f64> {
        let df = self.w32(d);
        let mut out = Vec::with_capacity(self.n);
        for blk in 0..self.blocks.len() {
            let z = self
                .runtime
                .margins(&self.blocks[blk], &df)
                .expect("margins artifact execution failed");
            let keep = (self.n - blk * self.runtime.batch).min(self.runtime.batch);
            out.extend(z[..keep].iter().map(|&v| v as f64));
        }
        out
    }

    fn hvp(&self, loss: Loss, z: &[f64], s: &[f64]) -> Vec<f64> {
        self.check_loss(loss);
        let sf = self.w32(s);
        let b = self.runtime.batch;
        let mut out = vec![0.0f64; s.len()];
        for blk in 0..self.blocks.len() {
            // re-pad the cached margins to the block shape (padding rows
            // have c = 0, so their z value is irrelevant)
            let lo = blk * b;
            let hi = (lo + b).min(self.n);
            let mut zf = vec![0.0f32; b];
            for (k, &zv) in z[lo..hi].iter().enumerate() {
                zf[k] = zv as f32;
            }
            let hv = self
                .runtime
                .hvp(&self.blocks[blk], &self.ys[blk], &self.cs[blk], &zf, &sf)
                .expect("hvp artifact execution failed");
            for (acc, &h) in out.iter_mut().zip(&hv) {
                *acc += h as f64;
            }
        }
        out
    }

    fn linesearch_eval(&self, loss: Loss, z: &[f64], e: &[f64], t: f64) -> (f64, f64) {
        self.check_loss(loss);
        let b = self.runtime.batch;
        let mut phi = 0.0f64;
        let mut dphi = 0.0f64;
        for blk in 0..self.blocks.len() {
            let lo = blk * b;
            let hi = (lo + b).min(self.n);
            let mut zf = vec![0.0f32; b];
            let mut ef = vec![0.0f32; b];
            for k in 0..(hi - lo) {
                zf[k] = z[lo + k] as f32;
                ef[k] = e[lo + k] as f32;
            }
            let (p, d) = self
                .runtime
                .linesearch(&zf, &ef, &self.ys[blk], &self.cs[blk], t as f32)
                .expect("linesearch artifact execution failed");
            phi += p as f64;
            dphi += d as f64;
        }
        (phi, dphi)
    }

    // no per-example access: SGD-style inner optimizers fall back to GD
    // (documented in optim::sgd)

    fn feature_counts(&self) -> Vec<u32> {
        self.feature_counts.clone()
    }
}

// Integration tests against real artifacts: rust/tests/aot_runtime.rs.
