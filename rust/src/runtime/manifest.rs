//! `artifacts/manifest.json` reader: the shape contract between
//! `python/compile/aot.py` and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json;

/// One AOT entry: HLO file plus declared input shapes / output names.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<String>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub features: usize,
    pub loss: String,
    pub entries: BTreeMap<String, Entry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let v = json::parse(text)?;
        let need = |k: &str| v.get(k).ok_or_else(|| format!("manifest missing {k:?}"));
        let batch = need("batch")?.as_usize().ok_or("batch not a number")?;
        let features = need("features")?.as_usize().ok_or("features not a number")?;
        let loss = need("loss")?.as_str().ok_or("loss not a string")?.to_string();
        let format = need("format")?.as_str().unwrap_or("");
        if format != "hlo-text/return-tuple" {
            return Err(format!("unsupported artifact format {format:?}"));
        }
        let mut entries = BTreeMap::new();
        let ents = need("entries")?.as_obj().ok_or("entries not an object")?;
        for (name, e) in ents {
            let file = e
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| format!("entry {name}: missing file"))?;
            let inputs = e
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| format!("entry {name}: missing inputs"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                        .unwrap_or_default()
                })
                .collect();
            let outputs = e
                .get("outputs")
                .and_then(|o| o.as_arr())
                .map(|o| {
                    o.iter()
                        .filter_map(|s| s.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default();
            entries.insert(
                name.clone(),
                Entry {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest {
            batch,
            features,
            loss,
            entries,
            dir: dir.to_path_buf(),
        })
    }

    pub fn entry(&self, prefix: &str) -> Result<&Entry, String> {
        self.entries
            .values()
            .find(|e| e.name.starts_with(prefix))
            .ok_or_else(|| format!("no artifact entry starting with {prefix:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch": 32, "features": 16, "loss": "squared_hinge",
      "format": "hlo-text/return-tuple",
      "entries": {
        "obj_grad_b32_f16": {
          "file": "obj_grad_b32_f16.hlo.txt",
          "inputs": [[32, 16], [32, 1], [32, 1], [16, 1]],
          "outputs": ["loss", "grad", "z"]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.batch, 32);
        assert_eq!(m.features, 16);
        assert_eq!(m.loss, "squared_hinge");
        let e = m.entry("obj_grad").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[0], vec![32, 16]);
        assert_eq!(e.outputs, vec!["loss", "grad", "z"]);
        assert_eq!(e.file, Path::new("/tmp/a/obj_grad_b32_f16.hlo.txt"));
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("hlo-text/return-tuple", "proto");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn missing_entry_reported() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m.entry("hvp").is_err());
    }
}
