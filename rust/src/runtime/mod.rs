//! Runtime: load and execute the AOT-compiled XLA artifacts from the
//! Layer-3 hot path.
//!
//! `python/compile/aot.py` lowers the Layer-2 JAX graphs (which embed
//! the Layer-1 Pallas kernels) to **HLO text** under `artifacts/`;
//! [`pjrt::AotRuntime`] loads them with
//! `HloModuleProto::from_text_file`, compiles once per entry on the
//! PJRT CPU client, and serves block-level loss/grad/Hv/line-search
//! evaluations. [`backend::DenseBlockShard`] adapts that to the
//! [`crate::objective::ShardCompute`] trait so every training method
//! can run on the AOT path unchanged (the dense mnist8m-like workloads
//! — DESIGN.md §5 explains why sparse shards stay native).
//!
//! Python never runs at serving/training time: once `make artifacts`
//! has produced the HLO text, the Rust binary is self-contained.

pub mod backend;
pub mod manifest;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use backend::DenseBlockShard;
pub use manifest::Manifest;
pub use pjrt::AotRuntime;
