//! PJRT client wrapper: compile the HLO-text artifacts once, execute
//! them from the hot path.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* (never
//! serialized protos — jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects) → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! All entries were lowered with `return_tuple=True`, so outputs are
//! unpacked with `to_tuple`.
//!
//! Execution is serialized behind a mutex: the CPU PJRT client is not
//! documented thread-safe for concurrent executes, and the simulated
//! cluster's virtual clock is unaffected by host-side serialization.

use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::manifest::Manifest;

/// Block-shape-specialized executables for the four AOT entries.
pub struct AotRuntime {
    /// rows per block (B)
    pub batch: usize,
    /// feature dimension (M)
    pub features: usize,
    /// loss the artifacts were lowered with
    pub loss: crate::loss::Loss,
    client_platform: String,
    exec: Mutex<Executables>,
}

struct Executables {
    margins: xla::PjRtLoadedExecutable,
    obj_grad: xla::PjRtLoadedExecutable,
    hvp: xla::PjRtLoadedExecutable,
    linesearch: xla::PjRtLoadedExecutable,
}

// SAFETY: the xla wrapper types hold raw PJRT pointers and an Rc'd
// client handle, so they are not auto-Send/Sync. Every access to them
// in this crate goes through `AotRuntime::exec`'s Mutex (including the
// Rc refcount: no clone of the client escapes the struct), so moving
// the whole bundle across threads and sharing &AotRuntime is sound.
// The PJRT CPU client itself is documented to tolerate calls from any
// single thread at a time, which the Mutex enforces.
unsafe impl Send for Executables {}

fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

impl AotRuntime {
    /// Load and compile every artifact under `dir` (see `make artifacts`).
    pub fn load(dir: &Path) -> Result<AotRuntime> {
        let manifest = Manifest::load(dir).map_err(anyhow::Error::msg)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |prefix: &str| -> Result<xla::PjRtLoadedExecutable> {
            let entry = manifest.entry(prefix).map_err(anyhow::Error::msg)?;
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .with_context(|| format!("parse {}", entry.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let exec = Executables {
            margins: compile("margins")?,
            obj_grad: compile("obj_grad")?,
            hvp: compile("hvp")?,
            linesearch: compile("linesearch")?,
        };
        let loss = crate::loss::Loss::from_name(&manifest.loss)
            .ok_or_else(|| anyhow::anyhow!("unknown loss {:?}", manifest.loss))?;
        Ok(AotRuntime {
            batch: manifest.batch,
            features: manifest.features,
            loss,
            client_platform: client.platform_name(),
            exec: Mutex::new(exec),
        })
    }

    pub fn platform(&self) -> &str {
        &self.client_platform
    }

    /// z = X·w for one (B, M) block. `w` length M.
    pub fn margins(&self, x: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch;
        let m = self.features;
        let lx = literal_2d(x, b, m)?;
        let lw = literal_2d(w, m, 1)?;
        let exec = self.exec.lock().unwrap();
        let result = exec.margins.execute::<xla::Literal>(&[lx, lw])?[0][0]
            .to_literal_sync()?;
        let z = result.to_tuple1()?;
        Ok(z.to_vec::<f32>()?)
    }

    /// (Σ c·l, Xᵀ(c·l'), z) for one block.
    pub fn obj_grad(
        &self,
        x: &[f32],
        y: &[f32],
        c: &[f32],
        w: &[f32],
    ) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        let b = self.batch;
        let m = self.features;
        let args = [
            literal_2d(x, b, m)?,
            literal_2d(y, b, 1)?,
            literal_2d(c, b, 1)?,
            literal_2d(w, m, 1)?,
        ];
        let exec = self.exec.lock().unwrap();
        let result = exec.obj_grad.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (loss, grad, z) = result.to_tuple3()?;
        Ok((
            loss.to_vec::<f32>()?[0],
            grad.to_vec::<f32>()?,
            z.to_vec::<f32>()?,
        ))
    }

    /// Hv = Xᵀ(c ⊙ l''(z) ⊙ (X·s)) for one block.
    pub fn hvp(
        &self,
        x: &[f32],
        y: &[f32],
        c: &[f32],
        z: &[f32],
        s: &[f32],
    ) -> Result<Vec<f32>> {
        let b = self.batch;
        let m = self.features;
        let args = [
            literal_2d(x, b, m)?,
            literal_2d(y, b, 1)?,
            literal_2d(c, b, 1)?,
            literal_2d(z, b, 1)?,
            literal_2d(s, m, 1)?,
        ];
        let exec = self.exec.lock().unwrap();
        let result = exec.hvp.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let hv = result.to_tuple1()?;
        Ok(hv.to_vec::<f32>()?)
    }

    /// (φ(t), φ'(t)) over one block's cached (z, e).
    pub fn linesearch(
        &self,
        z: &[f32],
        e: &[f32],
        y: &[f32],
        c: &[f32],
        t: f32,
    ) -> Result<(f32, f32)> {
        let b = self.batch;
        let args = [
            literal_2d(z, b, 1)?,
            literal_2d(e, b, 1)?,
            literal_2d(y, b, 1)?,
            literal_2d(c, b, 1)?,
            literal_2d(&[t], 1, 1)?,
        ];
        let exec = self.exec.lock().unwrap();
        let result = exec.linesearch.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (phi, dphi) = result.to_tuple2()?;
        Ok((phi.to_vec::<f32>()?[0], dphi.to_vec::<f32>()?[0]))
    }
}

// Integration tests against the real artifacts live in
// rust/tests/aot_runtime.rs (they need `make artifacts` to have run).
