//! Offline stub for the PJRT runtime (compiled when the `xla` feature
//! is off, which is the default).
//!
//! The real implementation in `pjrt.rs` links the `xla` extension
//! wrapper (plus `anyhow`), neither of which is available in the
//! dependency-free offline build. This stub preserves the exact public
//! surface [`crate::runtime::backend::DenseBlockShard`] and the CLI
//! use, but every entry point reports the runtime as unavailable at
//! *load* time — callers that never touch the AOT backend (the default
//! sparse path and all tier-1 tests) are unaffected.

use std::fmt;
use std::path::Path;

/// Error type mirroring the `anyhow::Error` surface the real runtime
/// uses: `Display` (including the `{:#}` alternate form used by the
/// CLI) and `Debug` for `.expect()` call sites.
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Stub of the block-shape-specialized AOT runtime. Construction always
/// fails, so the methods below are unreachable in practice; they exist
/// to keep [`super::backend::DenseBlockShard`] compiling unchanged.
pub struct AotRuntime {
    /// rows per block (B)
    pub batch: usize,
    /// feature dimension (M)
    pub features: usize,
    /// loss the artifacts were lowered with
    pub loss: crate::loss::Loss,
}

fn unavailable<T>() -> Result<T> {
    Err(RuntimeError(
        "AOT/PJRT runtime unavailable: this binary was built without the \
         `xla` cargo feature (see Cargo.toml). Use the sparse backend, or \
         rebuild with `--features xla` in an environment that provides \
         the xla extension."
            .into(),
    ))
}

impl AotRuntime {
    /// Always fails in the offline build.
    pub fn load(_dir: &Path) -> Result<AotRuntime> {
        unavailable()
    }

    pub fn platform(&self) -> &str {
        "unavailable"
    }

    /// z = X·w for one (B, M) block.
    pub fn margins(&self, _x: &[f32], _w: &[f32]) -> Result<Vec<f32>> {
        unavailable()
    }

    /// (Σ c·l, Xᵀ(c·l'), z) for one block.
    pub fn obj_grad(
        &self,
        _x: &[f32],
        _y: &[f32],
        _c: &[f32],
        _w: &[f32],
    ) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        unavailable()
    }

    /// Hv = Xᵀ(c ⊙ l''(z) ⊙ (X·s)) for one block.
    pub fn hvp(
        &self,
        _x: &[f32],
        _y: &[f32],
        _c: &[f32],
        _z: &[f32],
        _s: &[f32],
    ) -> Result<Vec<f32>> {
        unavailable()
    }

    /// (φ(t), φ'(t)) over one block's cached (z, e).
    pub fn linesearch(
        &self,
        _z: &[f32],
        _e: &[f32],
        _y: &[f32],
        _c: &[f32],
        _t: f32,
    ) -> Result<(f32, f32)> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = AotRuntime::load(Path::new("artifacts")).err().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("xla"), "{msg}");
    }
}
