//! Blocking client for the serving plane: one TCP connection, one
//! in-flight request (the batched protocol gets its throughput from
//! batch size and from many connections, not from pipelining).

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;

use crate::linalg::Csr;
use crate::loss::Loss;
use crate::net::wire::{self, Msg};

use super::csr_to_batch;

/// A connected scoring client. Request ids are per-connection
/// monotonic and echoed by the server, so a mismatched reply is a
/// protocol error, not silent misattribution.
pub struct ScoreClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl ScoreClient {
    pub fn connect(addr: &str) -> Result<ScoreClient, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(
            stream.try_clone().map_err(|e| format!("clone stream: {e}"))?,
        );
        Ok(ScoreClient { reader, writer: BufWriter::new(stream), next_id: 0 })
    }

    /// Score a batch already in CSR form. Returns (epoch, margins):
    /// the epoch is the published model the margins were computed
    /// against — the attribution handle for hot-swap tests.
    pub fn score_csr(&mut self, x: &Csr) -> Result<(u64, Vec<f64>), String> {
        let (row_nnz, col_idx, values) = csr_to_batch(x);
        self.score_parts(x.cols, row_nnz, col_idx, values)
    }

    /// Score a batch given as per-row (col, value) lists.
    pub fn score_rows(
        &mut self,
        cols: usize,
        rows: &[Vec<(u32, f32)>],
    ) -> Result<(u64, Vec<f64>), String> {
        let mut row_nnz = Vec::with_capacity(rows.len());
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for row in rows {
            row_nnz.push(row.len() as u32);
            for &(c, v) in row {
                col_idx.push(c);
                values.push(v);
            }
        }
        self.score_parts(cols, row_nnz, col_idx, values)
    }

    fn score_parts(
        &mut self,
        cols: usize,
        row_nnz: Vec<u32>,
        col_idx: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<(u64, Vec<f64>), String> {
        self.next_id += 1;
        let id = self.next_id;
        wire::send(
            &mut self.writer,
            &Msg::Score { id, cols, row_nnz, col_idx, values },
        )?;
        self.writer.flush().map_err(|e| format!("flush: {e}"))?;
        match wire::recv(&mut self.reader)? {
            Some(Msg::Scores { id: got, epoch, margins }) => {
                if got != id {
                    return Err(format!("reply id {got} for request {id}"));
                }
                Ok((epoch, margins))
            }
            Some(Msg::Abort { msg }) => Err(format!("server aborted: {msg}")),
            Some(other) => Err(format!("unexpected reply to Score: {other:?}")),
            None => Err("server closed the connection mid-request".to_string()),
        }
    }

    /// Publish new weights as the next model epoch (a retrain landing,
    /// or a test driving a hot swap). Returns the new epoch number.
    pub fn publish(
        &mut self,
        loss: Loss,
        lambda: f64,
        weights: Vec<f64>,
    ) -> Result<u64, String> {
        wire::send(&mut self.writer, &Msg::Publish { loss, lambda, weights })?;
        self.writer.flush().map_err(|e| format!("flush: {e}"))?;
        match wire::recv(&mut self.reader)? {
            Some(Msg::Published { epoch }) => Ok(epoch),
            Some(Msg::Abort { msg }) => Err(format!("server aborted: {msg}")),
            Some(other) => Err(format!("unexpected reply to Publish: {other:?}")),
            None => Err("server closed the connection mid-request".to_string()),
        }
    }

    /// Orderly close: the server drops the connection without an abort.
    pub fn shutdown(mut self) {
        let _ = wire::send(&mut self.writer, &Msg::Shutdown);
        let _ = self.writer.flush();
    }
}
