//! The serving plane: train-to-inference scoring over the wire.
//!
//! Training produces a [`crate::coordinator::artifact::ModelArtifact`];
//! this module turns one into a running scorer. A [`Front`] holds the
//! current model epoch behind an [`EpochPtr`] (an `ArcSwap`-style
//! atomically published pointer built from `Mutex<Arc<_>>` — the crate
//! is dependency-free) and a set of per-shard [`Replica`]s, each with
//! its own persistent [`ComputePool`], dispatched round-robin.
//!
//! Scoring reuses the PR-5 block kernels verbatim: a request batch
//! becomes a [`Csr`], is wrapped in a [`SparseShard`] on the replica's
//! pool, and scored with the same [`ShardCompute::margins`] code path
//! training uses — which is what makes served margins **bitwise equal**
//! to in-process margins on the same rows (the engine's fixed-order
//! block merge makes the thread count irrelevant to the bits).
//!
//! Hot model swap: [`EpochPtr::publish`] atomically replaces the
//! current epoch. Every batch snapshots the `Arc` once at entry, so
//! in-flight batches finish on the epoch they started with and every
//! `Scores` reply is attributable to exactly one published epoch —
//! no torn reads by construction.
//!
//! Between full retrains, [`online::OnlineUpdater`] absorbs streaming
//! examples with the paper's parallel-SGD special case (§4.3 / the
//! local-approximation scheme with one SGD pass as the inner solver)
//! and publishes the averaged result as a new epoch.
//!
//! Wire format: the v7 `Score`/`Scores`/`Publish`/`Published` frames
//! (`rust/src/net/README.md` has the diagrams); [`server`] is the
//! accept loop, [`client`] the blocking request client.

pub mod client;
pub mod online;
pub mod server;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::artifact::ModelArtifact;
use crate::linalg::Csr;
use crate::loss::Loss;
use crate::objective::engine::{self, ComputePool};
use crate::objective::{Shard, ShardCompute, SparseShard};

/// One published model epoch: immutable once built, shared by `Arc`.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeModel {
    /// monotonically increasing publish counter (first load = 1)
    pub epoch: u64,
    pub loss: Loss,
    pub lambda: f64,
    pub m: usize,
    pub weights: Vec<f64>,
}

impl ServeModel {
    /// Epoch 1: the artifact a serving process starts from.
    pub fn from_artifact(a: &ModelArtifact) -> ServeModel {
        ServeModel {
            epoch: 1,
            loss: a.loss,
            lambda: a.lambda,
            m: a.m,
            weights: a.weights.clone(),
        }
    }
}

/// Atomically published model pointer. `load` clones the `Arc` under a
/// briefly held lock (no reader ever blocks on a scoring pass);
/// `publish` swaps in a new epoch. In-flight batches keep scoring the
/// `Arc` they snapshotted — the old epoch is freed when its last
/// in-flight batch drops it.
pub struct EpochPtr {
    cur: Mutex<Arc<ServeModel>>,
}

impl EpochPtr {
    pub fn new(model: ServeModel) -> EpochPtr {
        EpochPtr { cur: Mutex::new(Arc::new(model)) }
    }

    /// Snapshot the current epoch (one `Arc` clone).
    pub fn load(&self) -> Arc<ServeModel> {
        self.cur.lock().unwrap().clone()
    }

    /// Atomically publish new weights as the next epoch; returns the
    /// new epoch number. The epoch counter is advanced under the same
    /// lock as the swap, so concurrent publishers serialize and every
    /// epoch number names exactly one weight vector.
    pub fn publish(&self, loss: Loss, lambda: f64, weights: Vec<f64>) -> u64 {
        let mut cur = self.cur.lock().unwrap();
        let epoch = cur.epoch + 1;
        let m = weights.len();
        *cur = Arc::new(ServeModel { epoch, loss, lambda, m, weights });
        epoch
    }
}

/// Validate and assemble a wire batch (per-row nnz counts + flat
/// column/value arrays) into a [`Csr`]. Rejects inconsistent counts
/// and out-of-range columns instead of panicking in a kernel.
pub fn batch_to_csr(
    cols: usize,
    row_nnz: &[u32],
    col_idx: Vec<u32>,
    values: Vec<f32>,
) -> Result<Csr, String> {
    let nnz: usize = row_nnz.iter().map(|&k| k as usize).sum();
    if col_idx.len() != nnz || values.len() != nnz {
        return Err(format!(
            "inconsistent score batch: row counts claim {nnz} nonzeros, got \
             {} columns / {} values",
            col_idx.len(),
            values.len()
        ));
    }
    if let Some(&bad) = col_idx.iter().find(|&&c| c as usize >= cols) {
        return Err(format!("column {bad} out of range for m = {cols}"));
    }
    let mut row_ptr = Vec::with_capacity(row_nnz.len() + 1);
    row_ptr.push(0usize);
    let mut acc = 0usize;
    for &k in row_nnz {
        acc += k as usize;
        row_ptr.push(acc);
    }
    Ok(Csr { rows: row_nnz.len(), cols, row_ptr, col_idx, values })
}

/// The inverse of [`batch_to_csr`]: flatten a [`Csr`] into the wire
/// batch triple (per-row nnz, columns, values).
pub fn csr_to_batch(x: &Csr) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
    let row_nnz = (0..x.rows).map(|i| x.row_nnz(i) as u32).collect();
    (row_nnz, x.col_idx.clone(), x.values.clone())
}

/// One model replica: a persistent block pool that scores batches with
/// the training margins kernel. Replicas share the published model
/// (immutable `Arc`), so "replica" costs a thread pool, not a weight
/// copy.
pub struct Replica {
    pool: Arc<ComputePool>,
}

impl Replica {
    /// `threads = 0` sizes the pool to all available cores, 1 is the
    /// serial inline pool (see [`engine::resolve_threads`]).
    pub fn new(threads: usize) -> Replica {
        Replica { pool: ComputePool::new(engine::resolve_threads(threads)) }
    }

    /// Score a batch: margins = X·w via the block-parallel training
    /// kernel. Bitwise identical to `SparseShard::margins` on the same
    /// rows for ANY pool size — it *is* `SparseShard::margins`.
    pub fn score(&self, model: &ServeModel, x: Csr) -> Vec<f64> {
        let rows = x.rows;
        let shard = Shard { x, y: vec![0.0; rows], c: vec![1.0; rows] };
        SparseShard::with_pool(shard, self.pool.clone()).margins(&model.weights)
    }
}

/// The round-robin front: N replicas behind an atomic dispatch
/// counter, one shared [`EpochPtr`]. This is the object a server
/// thread-per-connection loop shares ([`server::spawn`]).
pub struct Front {
    epoch: EpochPtr,
    replicas: Vec<Replica>,
    next: AtomicUsize,
}

impl Front {
    /// `replicas` pools of `threads` block threads each (both floors at
    /// 1 replica; `threads = 0` = all cores).
    pub fn new(model: ServeModel, replicas: usize, threads: usize) -> Front {
        let n = replicas.max(1);
        Front {
            epoch: EpochPtr::new(model),
            replicas: (0..n).map(|_| Replica::new(threads)).collect(),
            next: AtomicUsize::new(0),
        }
    }

    pub fn from_artifact(a: &ModelArtifact, replicas: usize, threads: usize) -> Front {
        Front::new(ServeModel::from_artifact(a), replicas, threads)
    }

    /// Current epoch snapshot (what the online updater trains from).
    pub fn model(&self) -> Arc<ServeModel> {
        self.epoch.load()
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Score one wire batch on the next replica. The epoch is
    /// snapshotted *before* assembly, so the reply's epoch is the one
    /// the margins were computed against even if a publish lands
    /// mid-batch.
    pub fn score_batch(
        &self,
        cols: usize,
        row_nnz: &[u32],
        col_idx: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<(u64, Vec<f64>), String> {
        let model = self.epoch.load();
        if cols != model.m {
            return Err(format!(
                "score batch has m = {cols}, the served model has m = {}",
                model.m
            ));
        }
        let x = batch_to_csr(cols, row_nnz, col_idx, values)?;
        let r = self.next.fetch_add(1, Ordering::Relaxed) % self.replicas.len();
        Ok((model.epoch, self.replicas[r].score(&model, x)))
    }

    /// Publish new weights as the next epoch (the `Publish` frame and
    /// the online updater both land here).
    pub fn publish(
        &self,
        loss: Loss,
        lambda: f64,
        weights: Vec<f64>,
    ) -> Result<u64, String> {
        let m = self.epoch.load().m;
        if weights.len() != m {
            return Err(format!(
                "published weights have m = {}, the served model has m = {m}",
                weights.len()
            ));
        }
        Ok(self.epoch.publish(loss, lambda, weights))
    }
}

/// Percentile over an ASCENDING-sorted latency sample (nearest-rank).
/// `p` in [0, 100]; returns 0 on an empty sample.
pub fn percentile_ns(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::artifact::Provenance;

    fn artifact(m: usize) -> ModelArtifact {
        ModelArtifact {
            loss: Loss::SquaredHinge,
            lambda: 1e-4,
            m,
            weights: (0..m).map(|j| (j as f64 + 1.0) * 0.25).collect(),
            provenance: Provenance {
                method: "tera".into(),
                dataset: "quick".into(),
                nodes: 2,
                seed: 7,
                outer_iters: 3,
                final_f: 1.0,
            },
        }
    }

    fn batch() -> Csr {
        Csr::from_rows(
            3,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![],
                vec![(1, -1.0), (2, 0.5)],
            ],
        )
    }

    #[test]
    fn served_margins_match_inproc_bitwise() {
        let front = Front::from_artifact(&artifact(3), 3, 2);
        let x = batch();
        let reference = SparseShard::new(Shard {
            x: x.clone(),
            y: vec![0.0; x.rows],
            c: vec![1.0; x.rows],
        })
        .margins(&front.model().weights);
        // every replica must produce the same bits as the serial
        // in-process reference
        for _ in 0..front.replicas() * 2 {
            let (row_nnz, cols_idx, vals) = csr_to_batch(&x);
            let (epoch, margins) =
                front.score_batch(3, &row_nnz, cols_idx, vals).unwrap();
            assert_eq!(epoch, 1);
            assert_eq!(margins.len(), reference.len());
            for (a, b) in margins.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn publish_advances_epoch_and_inflight_semantics() {
        let front = Front::from_artifact(&artifact(3), 1, 1);
        // a snapshot taken before the publish keeps the old epoch
        let before = front.model();
        let e2 = front
            .publish(Loss::SquaredHinge, 1e-4, vec![1.0, 2.0, 3.0])
            .unwrap();
        assert_eq!(before.epoch, 1, "in-flight batches finish on the old epoch");
        assert_eq!(e2, 2);
        assert_eq!(front.model().epoch, 2);
        assert_eq!(front.model().weights, vec![1.0, 2.0, 3.0]);
        // wrong dimension is refused, epoch unchanged
        assert!(front.publish(Loss::SquaredHinge, 1e-4, vec![1.0]).is_err());
        assert_eq!(front.model().epoch, 2);
    }

    #[test]
    fn batch_validation_rejects_garbage() {
        // counts that don't match the flat arrays
        assert!(batch_to_csr(3, &[2, 1], vec![0, 1], vec![1.0, 2.0]).is_err());
        // out-of-range column
        assert!(batch_to_csr(3, &[1], vec![3], vec![1.0]).is_err());
        // mismatched m at the front
        let front = Front::from_artifact(&artifact(3), 1, 1);
        assert!(front.score_batch(4, &[], vec![], vec![]).is_err());
        // the empty batch is legal and scores to an empty margin vector
        let (epoch, margins) = front.score_batch(3, &[], vec![], vec![]).unwrap();
        assert_eq!((epoch, margins.len()), (1, 0));
    }

    #[test]
    fn batch_roundtrips_through_wire_triple() {
        let x = batch();
        let (row_nnz, col_idx, values) = csr_to_batch(&x);
        let back = batch_to_csr(x.cols, &row_nnz, col_idx, values).unwrap();
        assert_eq!(back, x);
    }

    #[test]
    fn percentiles_nearest_rank() {
        assert_eq!(percentile_ns(&[], 99.0), 0);
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_ns(&s, 50.0), 50);
        assert_eq!(percentile_ns(&s, 99.0), 99);
        assert_eq!(percentile_ns(&s, 100.0), 100);
        assert_eq!(percentile_ns(&[7], 50.0), 7);
    }
}
