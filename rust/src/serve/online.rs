//! Online updates between full retrains: the paper's parallel-SGD
//! special case.
//!
//! The local-approximation scheme degenerates to parallel SGD when the
//! inner solver is a single stochastic pass (§4.3 uses exactly this as
//! the warm start). The serving twin: streaming examples accumulate in
//! a buffer; on flush the buffer is partitioned into contiguous blocks
//! (one per virtual node), each block runs a deterministic local SGD
//! pass *starting from the currently served weights*, and the per-block
//! results are example-count-weighted averaged — then published as the
//! next epoch through the same [`Front::publish`] path a full retrain
//! uses. Every step is sequential-deterministic (seeded per-part RNG,
//! fixed part order in the average), so an online epoch is a pure
//! function of (served model, buffered examples, seed).

use crate::loss::Loss;
use crate::util::rng::Pcg64;

use super::Front;

/// Streaming-example absorber. Not `Sync` by design: one updater owns
/// its buffer (feed it from one ingest thread); publication is the
/// only cross-thread effect and goes through the epoch pointer.
pub struct OnlineUpdater {
    parts: usize,
    eta0: f64,
    seed: u64,
    /// examples absorbed over the updater's lifetime (decays the step
    /// size across flushes, like a continued SGD schedule)
    absorbed: u64,
    rows: Vec<Vec<(u32, f32)>>,
    labels: Vec<f64>,
}

impl OnlineUpdater {
    /// `parts` virtual SGD nodes per flush (floored at 1), base step
    /// size `eta0`, deterministic `seed`.
    pub fn new(parts: usize, eta0: f64, seed: u64) -> OnlineUpdater {
        OnlineUpdater {
            parts: parts.max(1),
            eta0,
            seed,
            absorbed: 0,
            rows: Vec::new(),
            labels: Vec::new(),
        }
    }

    /// Buffer one example (sparse row + label).
    pub fn absorb(&mut self, row: Vec<(u32, f32)>, label: f64) {
        self.rows.push(row);
        self.labels.push(label);
    }

    /// Buffered examples not yet folded into a published epoch.
    pub fn pending(&self) -> usize {
        self.rows.len()
    }

    /// Fold the buffer into the served model and publish the result as
    /// a new epoch. Returns `Ok(None)` when the buffer is empty,
    /// `Ok(Some(epoch))` after a publish. The buffer is consumed
    /// either way; a validation error (feature index out of range)
    /// leaves the model unchanged.
    pub fn flush(&mut self, front: &Front) -> Result<Option<u64>, String> {
        if self.rows.is_empty() {
            return Ok(None);
        }
        let model = front.model();
        let m = model.m;
        let rows = std::mem::take(&mut self.rows);
        let labels = std::mem::take(&mut self.labels);
        for row in &rows {
            if let Some(&(c, _)) = row.iter().find(|&&(c, _)| c as usize >= m) {
                return Err(format!(
                    "online example has feature {c}, the served model has m = {m}"
                ));
            }
        }
        let n = rows.len();
        let parts = self.parts.min(n);
        // contiguous blocks, sizes differing by at most one — the same
        // scheme the example partitioner's contiguous strategy uses
        let base = n / parts;
        let extra = n % parts;
        let mut start = 0usize;
        let mut averaged = vec![0.0f64; m];
        for p in 0..parts {
            let len = base + usize::from(p < extra);
            let span = start..start + len;
            start += len;
            let wp = local_sgd(
                model.loss,
                model.lambda,
                &model.weights,
                &rows[span.clone()],
                &labels[span.clone()],
                self.eta0,
                self.absorbed,
                self.seed,
                p as u64,
            );
            // fixed part order ⇒ deterministic average
            let weight = len as f64 / n as f64;
            for (aj, wj) in averaged.iter_mut().zip(&wp) {
                *aj += weight * wj;
            }
        }
        self.absorbed += n as u64;
        front.publish(model.loss, model.lambda, averaged).map(Some)
    }
}

/// One deterministic local SGD pass over a block, warm-started from
/// `w0`. Regularization uses the lazy-scale representation w = s·v, so
/// a step costs O(nnz(x_i)) instead of O(m).
#[allow(clippy::too_many_arguments)]
fn local_sgd(
    loss: Loss,
    lambda: f64,
    w0: &[f64],
    rows: &[Vec<(u32, f32)>],
    labels: &[f64],
    eta0: f64,
    t0: u64,
    seed: u64,
    part: u64,
) -> Vec<f64> {
    let mut v = w0.to_vec();
    let mut s = 1.0f64;
    let mut order: Vec<usize> = (0..rows.len()).collect();
    Pcg64::with_stream(seed, part).shuffle(&mut order);
    for (k, &i) in order.iter().enumerate() {
        let t = t0 + k as u64;
        let eta = eta0 / (1.0 + t as f64).sqrt();
        let mut z = 0.0;
        for &(c, x) in &rows[i] {
            z += x as f64 * v[c as usize];
        }
        z *= s;
        let g = loss.dz(z, labels[i]);
        // shrink (the λ/2‖w‖² gradient), then the sparse data step
        s *= (1.0 - eta * lambda).max(1e-12);
        if s < 1e-9 {
            for vj in v.iter_mut() {
                *vj *= s;
            }
            s = 1.0;
        }
        if g != 0.0 {
            let a = -eta * g / s;
            for &(c, x) in &rows[i] {
                v[c as usize] += a * x as f64;
            }
        }
    }
    for vj in v.iter_mut() {
        *vj *= s;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::artifact::{ModelArtifact, Provenance};
    use crate::data::synth;
    use crate::objective::{Objective, Shard, SparseShard};

    fn zero_artifact(m: usize) -> ModelArtifact {
        ModelArtifact {
            loss: Loss::SquaredHinge,
            lambda: 1e-3,
            m,
            weights: vec![0.0; m],
            provenance: Provenance {
                method: "tera".into(),
                dataset: "quick".into(),
                nodes: 1,
                seed: 1,
                outer_iters: 0,
                final_f: f64::NAN,
            },
        }
    }

    fn dataset_rows(
        ds: &crate::data::Dataset,
    ) -> (Vec<Vec<(u32, f32)>>, Vec<f64>) {
        let rows = (0..ds.n()).map(|i| ds.x.row(i).collect()).collect();
        (rows, ds.y.clone())
    }

    #[test]
    fn flush_publishes_and_improves_objective() {
        let ds = synth::quick(300, 40, 8, 23);
        let front = Front::from_artifact(&zero_artifact(40), 2, 1);
        let mut upd = OnlineUpdater::new(4, 0.5, 11);
        let (rows, ys) = dataset_rows(&ds);
        for (row, y) in rows.into_iter().zip(ys) {
            upd.absorb(row, y);
        }
        assert_eq!(upd.pending(), 300);
        let epoch = upd.flush(&front).unwrap();
        assert_eq!(epoch, Some(2));
        assert_eq!(upd.pending(), 0);
        assert_eq!(upd.flush(&front).unwrap(), None, "empty buffer is a no-op");
        // the absorbed stream must beat the zero model on its own data
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let whole = SparseShard::new(Shard::whole(&ds));
        let (f_new, _) = obj.eval(&[&whole], &front.model().weights);
        let (f_zero, _) = obj.eval(&[&whole], &[0.0; 40]);
        assert!(f_new < f_zero, "{f_new} !< {f_zero}");
    }

    #[test]
    fn flush_is_deterministic() {
        let ds = synth::quick(120, 20, 6, 29);
        let run = || {
            let front = Front::from_artifact(&zero_artifact(20), 3, 2);
            let mut upd = OnlineUpdater::new(3, 0.25, 5);
            let (rows, ys) = dataset_rows(&ds);
            for (row, y) in rows.into_iter().zip(ys) {
                upd.absorb(row, y);
            }
            upd.flush(&front).unwrap();
            front.model().weights.clone()
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn out_of_range_feature_leaves_model_unchanged() {
        let front = Front::from_artifact(&zero_artifact(4), 1, 1);
        let mut upd = OnlineUpdater::new(2, 0.1, 1);
        upd.absorb(vec![(9, 1.0)], 1.0);
        assert!(upd.flush(&front).is_err());
        assert_eq!(front.model().epoch, 1);
    }
}
