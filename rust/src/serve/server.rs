//! The serving accept loop: thread-per-connection over the v7 frames.
//!
//! Each connection speaks the length-prefixed wire protocol
//! ([`crate::net::wire`]): `Score` requests are answered with `Scores`
//! (the margins plus the epoch they were computed against), `Publish`
//! atomically swaps in a new model epoch for EVERY connection and is
//! acknowledged with `Published`, `Shutdown` (or a clean EOF) closes
//! the connection. Malformed traffic gets an `Abort` with the reason
//! and the connection is dropped — one bad client never takes the
//! front down.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::net::wire::{self, Msg};

use super::Front;

/// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and run the
/// accept loop on a background thread. Returns the bound address —
/// what a [`super::client::ScoreClient`] connects to — and the accept
/// thread's handle. The loop runs for the life of the process.
pub fn spawn(front: Arc<Front>, addr: &str) -> Result<(SocketAddr, JoinHandle<()>), String> {
    let listener =
        TcpListener::bind(addr).map_err(|e| format!("serve bind {addr}: {e}"))?;
    let local = listener
        .local_addr()
        .map_err(|e| format!("serve local_addr: {e}"))?;
    let handle = std::thread::spawn(move || accept_loop(listener, front));
    Ok((local, handle))
}

fn accept_loop(listener: TcpListener, front: Arc<Front>) {
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let front = front.clone();
                std::thread::spawn(move || {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "?".to_string());
                    if let Err(e) = handle_conn(&front, stream) {
                        eprintln!("serve: connection {peer}: {e}");
                    }
                });
            }
            Err(e) => eprintln!("serve: accept: {e}"),
        }
    }
}

/// One connection's frame loop.
fn handle_conn(front: &Front, stream: TcpStream) -> Result<(), String> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(
        stream.try_clone().map_err(|e| format!("clone stream: {e}"))?,
    );
    let mut writer = BufWriter::new(stream);
    loop {
        let msg = match wire::recv(&mut reader)? {
            Some(m) => m,
            None => return Ok(()), // clean EOF
        };
        match msg {
            Msg::Score { id, cols, row_nnz, col_idx, values } => {
                match front.score_batch(cols, &row_nnz, col_idx, values) {
                    Ok((epoch, margins)) => {
                        wire::send(&mut writer, &Msg::Scores { id, epoch, margins })?;
                        writer.flush().map_err(|e| format!("flush: {e}"))?;
                    }
                    Err(msg) => return abort(&mut writer, msg),
                }
            }
            Msg::Publish { loss, lambda, weights } => {
                match front.publish(loss, lambda, weights) {
                    Ok(epoch) => {
                        wire::send(&mut writer, &Msg::Published { epoch })?;
                        writer.flush().map_err(|e| format!("flush: {e}"))?;
                    }
                    Err(msg) => return abort(&mut writer, msg),
                }
            }
            Msg::Shutdown => return Ok(()),
            other => {
                return abort(
                    &mut writer,
                    format!("unexpected frame on a serving connection: {other:?}"),
                )
            }
        }
    }
}

fn abort(writer: &mut impl Write, msg: String) -> Result<(), String> {
    let _ = wire::send(writer, &Msg::Abort { msg: msg.clone() });
    let _ = writer.flush();
    Err(msg)
}
