//! Declarative command-line flag parser (replaces `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean switches, defaults,
//! required flags, positional arguments, subcommands, and auto-generated
//! `--help` text. Every binary in `rust/src/bin/` and `examples/` builds
//! its interface from this.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    required: bool,
    is_switch: bool,
}

/// A declarative CLI: flags + positionals + optional subcommands.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
    positionals: Vec<(String, String)>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// An option flag with a default value.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            required: false,
            is_switch: false,
        });
        self
    }

    /// A required option flag.
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            required: true,
            is_switch: false,
        });
        self
    }

    /// A boolean switch (present = true).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            required: false,
            is_switch: true,
        });
        self
    }

    /// A positional argument (named only for help text).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn help_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} — {}", self.program, self.about);
        let _ = write!(out, "\nUSAGE:\n  {} [FLAGS]", self.program);
        for (p, _) in &self.positionals {
            let _ = write!(out, " <{p}>");
        }
        let _ = writeln!(out, "\n\nFLAGS:");
        for f in &self.flags {
            let kind = if f.is_switch {
                String::new()
            } else if let Some(d) = &f.default {
                format!(" <value, default {d}>")
            } else {
                " <value, required>".to_string()
            };
            let _ = writeln!(out, "  --{}{}\n      {}", f.name, kind, f.help);
        }
        let _ = writeln!(out, "  --help\n      print this message");
        for (p, h) in &self.positionals {
            let _ = writeln!(out, "\nARGS:\n  <{p}>  {h}");
        }
        out
    }

    /// Parse an explicit argument list (without argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        for f in &self.flags {
            if let Some(d) = &f.default {
                args.values.insert(f.name.clone(), d.clone());
            }
            if f.is_switch {
                args.switches.insert(f.name.clone(), false);
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.help_text()))?;
                if spec.is_switch {
                    if inline.is_some() {
                        return Err(format!("switch --{name} takes no value"));
                    }
                    args.switches.insert(name, true);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("flag --{name} expects a value"))?,
                    };
                    args.values.insert(name, value);
                }
            } else {
                args.positionals.push(tok);
            }
        }
        for f in &self.flags {
            if f.required && !args.values.contains_key(&f.name) {
                return Err(format!(
                    "missing required flag --{}\n\n{}",
                    f.name,
                    self.help_text()
                ));
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()`, printing help/errors and exiting on failure.
    pub fn parse(&self) -> Args {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with(&self.program) { 0 } else { 2 });
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer, got {:?}", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number, got {:?}", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer, got {:?}", self.get(name)))
    }

    /// Comma-separated list of usize (e.g. `--nodes 8,16,32`).
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name}: bad integer {s:?}"))
            })
            .collect()
    }

    pub fn on(&self, name: &str) -> bool {
        *self
            .switches
            .get(name)
            .unwrap_or_else(|| panic!("switch --{name} not declared"))
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("prog", "test program")
            .flag("nodes", "8", "node count")
            .flag("gamma", "500", "comm/comp ratio")
            .switch("verbose", "chatty")
            .required("dataset", "which dataset")
            .positional("out", "output path")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli()
            .parse_from(sv(&["--dataset", "rcv", "--nodes=32", "out.json"]))
            .unwrap();
        assert_eq!(a.get_usize("nodes"), 32);
        assert_eq!(a.get_f64("gamma"), 500.0);
        assert_eq!(a.get("dataset"), "rcv");
        assert!(!a.on("verbose"));
        assert_eq!(a.positional(0), Some("out.json"));
    }

    #[test]
    fn switch_toggles() {
        let a = cli()
            .parse_from(sv(&["--dataset", "url", "--verbose"]))
            .unwrap();
        assert!(a.on("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse_from(sv(&["--nodes", "2"])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cli()
            .parse_from(sv(&["--dataset", "x", "--bogus", "1"]))
            .is_err());
    }

    #[test]
    fn switch_with_value_errors() {
        assert!(cli()
            .parse_from(sv(&["--dataset", "x", "--verbose=1"]))
            .is_err());
    }

    #[test]
    fn help_lists_flags() {
        let h = cli().help_text();
        assert!(h.contains("--nodes"));
        assert!(h.contains("--dataset"));
        assert!(h.contains("required"));
    }

    #[test]
    fn list_flag() {
        let c = Cli::new("p", "t").flag("ps", "8,16", "list");
        let a = c.parse_from(sv(&["--ps", "8,64,128"])).unwrap();
        assert_eq!(a.get_usize_list("ps"), vec![8, 64, 128]);
    }
}
