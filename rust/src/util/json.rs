//! Minimal JSON: a writer for experiment reports and a reader for the
//! AOT `artifacts/manifest.json` (replaces `serde_json`).
//!
//! The reader supports the full JSON grammar minus exotic escapes
//! (\uXXXX surrogate pairs are decoded; numbers parse as f64).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let pad_close = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    out.push_str(&pad);
                    item.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad_close);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad_close);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(s).map_err(|e| e.to_string())?;
                    let ch = text.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let v = obj(vec![
            ("name", Json::Str("fadl".into())),
            ("iters", Json::Num(42.0)),
            ("loss", arr_f64(&[1.0, 0.5, 0.25])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = v.pretty();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"batch": 256, "entries": {"m": {"file": "m.hlo.txt", "inputs": [[256, 784], [784, 1]]}}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(256));
        let ent = v.get("entries").unwrap().get("m").unwrap();
        assert_eq!(ent.get("file").unwrap().as_str(), Some("m.hlo.txt"));
        let ins = ent.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].as_arr().unwrap()[1].as_usize(), Some(784));
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te".into());
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let v = parse("[-1.5, 2e3, 1.25e-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
        assert_eq!(a[2].as_f64(), Some(0.0125));
    }
}
