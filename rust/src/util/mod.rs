//! Offline-build substrate utilities.
//!
//! The build environment is fully offline (DESIGN.md §8), so the usual
//! ecosystem crates (`rand`, `clap`, `serde`/`toml`, `proptest`) are
//! replaced by small, fully-tested in-repo implementations.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod sha256;
pub mod toml;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev_basic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
