//! Minimal shrinking property-test harness (replaces `proptest`).
//!
//! Used by the coordinator-invariant tests (routing of shards,
//! partition/batching bookkeeping, AllReduce correctness, optimizer
//! descent properties). A property runs against `cases` random inputs
//! drawn from a [`Gen`]; on failure the harness greedily shrinks the
//! input before reporting, so failures are small and readable.

use super::rng::Pcg64;

/// A generator: draws a value and can propose smaller variants of one.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn draw(&self, rng: &mut Pcg64) -> Self::Value;
    /// Candidate shrinks, in decreasing preference order.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Configuration for a property run.
pub struct Runner {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Runner {
            cases: 64,
            seed: 0x5eed,
            max_shrink_steps: 200,
        }
    }
}

impl Runner {
    pub fn new(cases: usize, seed: u64) -> Self {
        Runner {
            cases,
            seed,
            ..Default::default()
        }
    }

    /// Check `prop` over `cases` random draws; panic with the (shrunk)
    /// counterexample on failure.
    pub fn run<G: Gen, F: Fn(&G::Value) -> Result<(), String>>(&self, gen: &G, prop: F) {
        let mut rng = Pcg64::new(self.seed);
        for case in 0..self.cases {
            let value = gen.draw(&mut rng);
            if let Err(msg) = prop(&value) {
                let (shrunk, steps, last_msg) = self.shrink_loop(gen, value, msg, &prop);
                panic!(
                    "property failed (case {case}, after {steps} shrink steps):\n  \
                     input: {shrunk:?}\n  error: {last_msg}"
                );
            }
        }
    }

    fn shrink_loop<G: Gen, F: Fn(&G::Value) -> Result<(), String>>(
        &self,
        gen: &G,
        mut value: G::Value,
        mut msg: String,
        prop: &F,
    ) -> (G::Value, usize, String) {
        let mut steps = 0;
        'outer: while steps < self.max_shrink_steps {
            for cand in gen.shrink(&value) {
                if let Err(m) = prop(&cand) {
                    value = cand;
                    msg = m;
                    steps += 1;
                    continue 'outer;
                }
            }
            break;
        }
        (value, steps, msg)
    }
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// usize in [lo, hi], shrinking toward lo.
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;

    fn draw(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 in [lo, hi], shrinking toward 0 (clamped to range).
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;

    fn draw(&self, rng: &mut Pcg64) -> f64 {
        rng.range_f64(self.0, self.1)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        let target = 0.0f64.clamp(self.0, self.1);
        if (v - target).abs() < 1e-9 {
            Vec::new()
        } else {
            vec![target, target + (v - target) / 2.0]
        }
    }
}

/// Vec<f64> with length in [min_len, max_len], elements in [lo, hi];
/// shrinks by halving length, then zeroing elements.
pub struct VecF64 {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f64,
    pub hi: f64,
}

impl Gen for VecF64 {
    type Value = Vec<f64>;

    fn draw(&self, rng: &mut Pcg64) -> Vec<f64> {
        let n = self.min_len + rng.below(self.max_len - self.min_len + 1);
        (0..n).map(|_| rng.range_f64(self.lo, self.hi)).collect()
    }

    fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let mut half = v.clone();
            half.truncate((v.len() / 2).max(self.min_len));
            out.push(half);
            let mut minus1 = v.clone();
            minus1.pop();
            out.push(minus1);
        }
        if let Some(i) = v.iter().position(|&x| x != 0.0) {
            if self.lo <= 0.0 && self.hi >= 0.0 {
                let mut z = v.clone();
                z[i] = 0.0;
                out.push(z);
            }
        }
        out
    }
}

/// Pair generator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn draw(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.draw(rng), self.1.draw(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Runner::default().run(&UsizeRange(0, 100), |&n| {
            if n <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let result = std::panic::catch_unwind(|| {
            Runner::default().run(&UsizeRange(0, 1000), |&n| {
                if n < 50 {
                    Ok(())
                } else {
                    Err(format!("{n} too big"))
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink must land on the boundary value 50
        assert!(msg.contains("input: 50"), "{msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecF64 {
            min_len: 2,
            max_len: 10,
            lo: -1.0,
            hi: 1.0,
        };
        Runner::new(200, 1).run(&g, |v| {
            if v.len() >= 2 && v.len() <= 10 && v.iter().all(|x| (-1.0..=1.0).contains(x)) {
                Ok(())
            } else {
                Err("bounds violated".into())
            }
        });
    }

    #[test]
    fn pair_gen_shrinks_both_sides() {
        let g = Pair(UsizeRange(0, 10), UsizeRange(0, 10));
        let mut rng = Pcg64::new(2);
        let v = g.draw(&mut rng);
        if v.0 > 0 || v.1 > 0 {
            assert!(!g.shrink(&v).is_empty());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = UsizeRange(0, 1_000_000);
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        assert_eq!(g.draw(&mut a), g.draw(&mut b));
    }
}
