//! Deterministic pseudo-random number generation (replaces `rand`).
//!
//! `Pcg64` is the PCG-XSL-RR 128/64 generator: tiny state, excellent
//! statistical quality, and — crucially for this repo — *reproducible
//! experiment streams*: every dataset generator, partitioner, SGD
//! shuffle, and property test derives its stream from an explicit seed,
//! so every figure in EXPERIMENTS.md can be regenerated bit-for-bit.

/// PCG-XSL-RR 128/64 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give uncorrelated
    /// streams (the default increment is the PCG reference constant).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id (odd-ified
    /// internally). Used to split independent per-node streams.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (e.g. one per worker node).
    pub fn split(&mut self, idx: u64) -> Pcg64 {
        Pcg64::with_stream(self.next_u64(), idx.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). Uses Lemire rejection for unbiasedness.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (polar-free variant is fine here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p) as ±1.0 labels (p = P[+1]).
    pub fn label(&mut self, p_pos: f64) -> f64 {
        if self.f64() < p_pos {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm order
    /// is not needed; simple partial shuffle keeps it O(n) worst case).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Zipf-like power-law sample over [0, n): feature popularity in the
    /// synthetic sparse datasets follows this (DESIGN.md §4).
    pub fn zipf(&mut self, n: usize, exponent: f64) -> usize {
        // Inverse-CDF on a continuous Pareto approximation, clamped.
        let u = self.f64().max(1e-12);
        let x = (1.0 - u).powf(-1.0 / (exponent - 1.0)) - 1.0;
        let scaled = x * n as f64 / 50.0;
        (scaled as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let xs: Vec<f64> = (0..200_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::stddev(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((s - 1.0).abs() < 0.02, "std {s}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 50));
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::new(13);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Pcg64::new(17);
        let n = 1000;
        let mut lows = 0usize;
        for _ in 0..10_000 {
            let z = r.zipf(n, 1.5);
            assert!(z < n);
            if z < n / 10 {
                lows += 1;
            }
        }
        // power law: the bottom decile of ids should dominate
        assert!(lows > 5_000, "lows {lows}");
    }

    #[test]
    fn label_balance() {
        let mut r = Pcg64::new(23);
        let pos = (0..10_000).filter(|_| r.label(0.3) > 0.0).count();
        assert!((2_500..3_500).contains(&pos));
    }
}
