//! Minimal TOML-subset parser for the experiment config system
//! (replaces `toml` + `serde`).
//!
//! Supported grammar (everything the configs in `configs/` use):
//! `[section]` and `[section.sub]` headers, `key = value` with string,
//! integer, float, boolean, and homogeneous-array values, `#` comments,
//! and bare/quoted keys. Keys are flattened to `section.sub.key`.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A flattened TOML document: `section.key -> Value`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Document {
    pub entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Keys under a section prefix (e.g. `section("dataset")`).
    pub fn section(&self, prefix: &str) -> impl Iterator<Item = (&str, &Value)> {
        let want = format!("{prefix}.");
        self.entries.iter().filter_map(move |(k, v)| {
            k.strip_prefix(&want).map(|rest| (rest, v))
        })
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document, String> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim().trim_matches('"');
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let value = parse_value(val.trim())
            .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
        doc.entries.insert(full, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array {s:?}"))?
            .trim();
        if body.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> = split_array_items(body)
            .into_iter()
            .map(|it| parse_value(it.trim()))
            .collect();
        return Ok(Value::Array(items?));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unrecognized value {s:?}"))
}

fn split_array_items(body: &str) -> Vec<&str> {
    // split on commas not inside quotes or nested brackets
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                items.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&body[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = parse(
            r#"
# experiment config
name = "fig5"           # trailing comment
[dataset]
kind = "kdd2010"
scale = 0.01
[cluster]
nodes = 128
gamma = 1_000
pipelined = true
sweep = [8, 16, 32]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "fig5");
        assert_eq!(doc.str_or("dataset.kind", ""), "kdd2010");
        assert_eq!(doc.f64_or("dataset.scale", 0.0), 0.01);
        assert_eq!(doc.usize_or("cluster.nodes", 0), 128);
        assert_eq!(doc.f64_or("cluster.gamma", 0.0), 1000.0);
        assert!(doc.bool_or("cluster.pipelined", false));
        let sweep = doc.get("cluster.sweep").unwrap().as_array().unwrap();
        assert_eq!(sweep.len(), 3);
        assert_eq!(sweep[2].as_usize(), Some(32));
    }

    #[test]
    fn string_with_hash_and_escapes() {
        let doc = parse(r#"path = "a#b\"c""#).unwrap();
        assert_eq!(doc.str_or("path", ""), "a#b\"c");
    }

    #[test]
    fn defaults_apply() {
        let doc = parse("").unwrap();
        assert_eq!(doc.usize_or("cluster.nodes", 7), 7);
        assert_eq!(doc.str_or("x", "d"), "d");
    }

    #[test]
    fn section_iteration() {
        let doc = parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<&str> = doc.section("a").map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["x", "y"]);
    }

    #[test]
    fn error_cases() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("= 3").is_err());
        assert!(parse("x = \"open").is_err());
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("x = what").is_err());
    }

    #[test]
    fn negative_and_float_forms() {
        let doc = parse("a = -4\nb = 1.25e-6\nc = -0.5").unwrap();
        assert_eq!(doc.get("a").unwrap().as_i64(), Some(-4));
        assert_eq!(doc.f64_or("b", 0.0), 1.25e-6);
        assert_eq!(doc.f64_or("c", 0.0), -0.5);
    }
}
