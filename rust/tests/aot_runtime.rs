//! Integration tests over the AOT/PJRT path: artifacts → runtime →
//! DenseBlockShard → training methods. Requires `make artifacts` to
//! have produced `artifacts/` (the Makefile runs it before tests);
//! every test skips with a notice when artifacts are absent so plain
//! `cargo test` still passes in a fresh checkout.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use fadl::cluster::{Cluster, CostModel};
use fadl::data::synth::{self, DatasetSpec, ValueDist};
use fadl::loss::Loss;
use fadl::methods::{fadl::Fadl, TrainContext, Trainer};
use fadl::objective::{Objective, Shard, ShardCompute, SparseShard};
use fadl::runtime::{AotRuntime, DenseBlockShard};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        None
    }
}

fn runtime() -> Option<Arc<AotRuntime>> {
    artifacts_dir().map(|d| Arc::new(AotRuntime::load(&d).expect("load artifacts")))
}

/// A dense dataset matching the artifact feature dimension.
fn dense_dataset(rt: &AotRuntime, n: usize) -> fadl::data::Dataset {
    synth::generate(&DatasetSpec {
        name: "dense-test".into(),
        n,
        m: rt.features,
        avg_row_nnz: rt.features,
        lambda: 1e-3,
        values: ValueDist::Pixel,
        label_noise: 0.05,
        zipf_exponent: 1.0,
        seed: 99,
    })
}

#[test]
fn aot_matches_native_backend_numerics() {
    let Some(rt) = runtime() else { return };
    let ds = dense_dataset(&rt, 300); // 2 blocks: one full + one ragged
    let shard = Shard::whole(&ds);
    let native = SparseShard::new(shard.clone());
    let aot = DenseBlockShard::new(rt.clone(), &shard);
    assert_eq!(aot.num_blocks(), 2);
    assert_eq!(aot.n(), 300);

    let mut rng = fadl::util::rng::Pcg64::new(3);
    let w: Vec<f64> = (0..rt.features).map(|_| 0.05 * rng.normal()).collect();

    let (l_native, g_native, z_native) = native.loss_grad(rt.loss, &w);
    let (l_aot, g_aot, z_aot) = aot.loss_grad(rt.loss, &w);
    assert!(
        (l_native - l_aot).abs() < 1e-3 * l_native.abs().max(1.0),
        "loss {l_native} vs {l_aot}"
    );
    assert_eq!(z_native.len(), z_aot.len());
    for i in (0..z_native.len()).step_by(37) {
        assert!((z_native[i] - z_aot[i]).abs() < 1e-3, "z[{i}]");
    }
    for j in (0..g_native.len()).step_by(31) {
        assert!(
            (g_native[j] - g_aot[j]).abs() < 1e-2 * g_native[j].abs().max(1.0),
            "g[{j}]: {} vs {}",
            g_native[j],
            g_aot[j]
        );
    }

    // hvp agreement at the cached margins
    let s: Vec<f64> = (0..rt.features).map(|_| rng.normal()).collect();
    let hv_native = native.hvp(rt.loss, &z_native, &s);
    let hv_aot = aot.hvp(rt.loss, &z_aot, &s);
    for j in (0..hv_native.len()).step_by(53) {
        assert!(
            (hv_native[j] - hv_aot[j]).abs() < 5e-2 * hv_native[j].abs().max(1.0),
            "hv[{j}]: {} vs {}",
            hv_native[j],
            hv_aot[j]
        );
    }

    // line-search agreement over cached margins
    let e_native = native.margins(&s);
    let e_aot = aot.margins(&s);
    for t in [0.0, 0.5, 1.5] {
        let (p_native, d_native) = native.linesearch_eval(rt.loss, &z_native, &e_native, t);
        let (p_aot, d_aot) = aot.linesearch_eval(rt.loss, &z_aot, &e_aot, t);
        assert!(
            (p_native - p_aot).abs() < 1e-2 * p_native.abs().max(1.0),
            "phi({t})"
        );
        assert!(
            (d_native - d_aot).abs() < 1e-2 * d_native.abs().max(1.0).max(p_native.abs()),
            "dphi({t}): {d_native} vs {d_aot}"
        );
    }
}

#[test]
fn fadl_trains_identically_enough_on_both_backends() {
    let Some(rt) = runtime() else { return };
    let ds = dense_dataset(&rt, 512);
    let p = 2;
    let part = fadl::data::partition::ExamplePartition::build(
        ds.n(),
        p,
        fadl::data::partition::Strategy::Contiguous,
        0,
    );
    let obj = Objective::new(1e-3, Loss::SquaredHinge);
    let run = |aot: bool| {
        let workers: Vec<Box<dyn ShardCompute>> = (0..p)
            .map(|i| {
                let shard = Shard::from_dataset(&ds, &part.assignments[i], &part.weights[i]);
                if aot {
                    Box::new(DenseBlockShard::new(rt.clone(), &shard)) as Box<dyn ShardCompute>
                } else {
                    Box::new(SparseShard::new(shard)) as Box<dyn ShardCompute>
                }
            })
            .collect();
        let cluster = Cluster::new(workers, CostModel::default());
        let ctx = TrainContext {
            max_outer: 6,
            eps_g: 1e-10,
            ..TrainContext::new(&cluster, obj)
        };
        let method = Fadl {
            warm_start: false, // block backend has no per-example SGD
            ..Default::default()
        };
        let (_, trace) = method.train(&ctx);
        trace
    };
    let native = run(false);
    let aot = run(true);
    assert_eq!(native.records.len(), aot.records.len());
    // the very first record (pre-step) must agree to f32 tolerance
    assert!(
        (native.records[0].f - aot.records[0].f).abs()
            < 1e-3 * native.records[0].f.abs().max(1.0),
        "initial f: {} vs {}",
        native.records[0].f,
        aot.records[0].f
    );
    // CG inside TRON is chaotic w.r.t. f32 rounding, so the *paths*
    // may diverge; the contract is that both are monotone descent runs
    // that make comparable progress.
    for t in [&native, &aot] {
        for w in t.records.windows(2) {
            assert!(w[1].f <= w[0].f + 1e-6, "non-monotone");
        }
    }
    let drop_native = native.records[0].f - native.best_f();
    let drop_aot = aot.records[0].f - aot.best_f();
    assert!(
        drop_aot > 0.5 * drop_native,
        "AOT backend made too little progress: {drop_aot} vs {drop_native}"
    );
}

#[test]
fn runtime_rejects_dimension_mismatch() {
    let Some(rt) = runtime() else { return };
    let ds = synth::quick(32, rt.features + 1, 8, 1);
    let shard = Shard::whole(&ds);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        DenseBlockShard::new(rt.clone(), &shard)
    }));
    assert!(result.is_err(), "mismatched m must panic with a clear message");
}

#[test]
fn margins_artifact_agrees_with_csr() {
    let Some(rt) = runtime() else { return };
    let ds = dense_dataset(&rt, 256);
    let shard = Shard::whole(&ds);
    let native = SparseShard::new(shard.clone());
    let aot = DenseBlockShard::new(rt.clone(), &shard);
    let mut rng = fadl::util::rng::Pcg64::new(8);
    let d: Vec<f64> = (0..rt.features).map(|_| rng.normal()).collect();
    let e_native = native.margins(&d);
    let e_aot = aot.margins(&d);
    for i in (0..e_native.len()).step_by(17) {
        assert!(
            (e_native[i] - e_aot[i]).abs() < 2e-2 * e_native[i].abs().max(1.0),
            "e[{i}]: {} vs {}",
            e_native[i],
            e_aot[i]
        );
    }
}
