//! Cross-module integration tests: the driver pipeline, every method on
//! every Table-1 dataset shape, trace serialization, and the
//! cross-method agreement that everything optimizes the same objective.

use fadl::coordinator::config::Config;
use fadl::coordinator::driver;
use fadl::linalg;
use fadl::metrics::auprc::auprc_of_model;

fn small_cfg(dataset: &str, method: &str, p: usize) -> Config {
    Config {
        dataset: dataset.into(),
        scale: 5e-5,
        nodes: p,
        method: method.into(),
        max_outer: 25,
        eps_g: 1e-9,
        ..Default::default()
    }
}

#[test]
fn all_methods_agree_on_the_optimum() {
    // FADL, TERA and ADMM all minimize eq. (8); run each to (near)
    // convergence on the same data and require consistent objectives.
    let mut finals = Vec::new();
    for method in ["fadl", "tera", "admm"] {
        let cfg = Config {
            quick_n: 400,
            quick_m: 50,
            quick_nnz: 10,
            method: method.into(),
            nodes: 4,
            max_outer: 80,
            eps_g: 1e-10,
            lambda: Some(1e-2),
            ..Default::default()
        };
        let exp = driver::prepare(&cfg).unwrap();
        let (_, trace) = driver::run(&exp).unwrap();
        finals.push((method, trace.best_f()));
    }
    let f0 = finals[0].1;
    for (method, f) in &finals {
        assert!(
            (f - f0).abs() / f0 < 5e-3,
            "{method}: {f} vs fadl {f0}"
        );
    }
}

#[test]
fn every_paper_dataset_shape_trains() {
    for dataset in ["kdd2010", "url", "webspam", "mnist8m", "rcv"] {
        let cfg = small_cfg(dataset, "fadl", 4);
        let exp = driver::prepare(&cfg).unwrap();
        let (_, trace) = driver::run(&exp).unwrap();
        let first = trace.records.first().unwrap().f;
        let last = trace.records.last().unwrap().f;
        assert!(last < first, "{dataset}: {first} -> {last}");
        assert!(last.is_finite());
    }
}

#[test]
fn solutions_generalize_above_chance() {
    let cfg = Config {
        quick_n: 2_000,
        quick_m: 200,
        quick_nnz: 15,
        nodes: 8,
        max_outer: 30,
        ..Default::default()
    };
    let exp = driver::prepare(&cfg).unwrap();
    let (w, _) = driver::run(&exp).unwrap();
    let base_rate = exp.test.positive_fraction();
    let auprc = auprc_of_model(&exp.test, &w);
    assert!(
        auprc > base_rate + 0.15,
        "AUPRC {auprc} vs base rate {base_rate}"
    );
}

#[test]
fn comm_pass_ordering_matches_table3() {
    // per outer iteration: TERA ≥ 3 passes (grad + CG), FADL = 2,
    // CoCoA = ADMM = 1 — the core cost claim of the paper.
    let passes_per_iter = |method: &str| {
        let mut cfg = small_cfg("url", method, 4);
        cfg.warm_start = false;
        cfg.max_outer = 4;
        let exp = driver::prepare(&cfg).unwrap();
        let (_, trace) = driver::run(&exp).unwrap();
        let r = &trace.records;
        (r.last().unwrap().comm_passes - r[0].comm_passes) / (r.len() - 1) as f64
    };
    let fadl = passes_per_iter("fadl");
    let tera = passes_per_iter("tera");
    let admm = passes_per_iter("admm");
    let cocoa = passes_per_iter("cocoa");
    assert!((fadl - 2.0).abs() < 1e-9, "fadl {fadl}");
    assert!(tera >= 3.0, "tera {tera}");
    assert!((admm - 1.0).abs() < 1e-9, "admm {admm}");
    assert!((cocoa - 1.0).abs() < 1e-9, "cocoa {cocoa}");
}

#[test]
fn fadl_beats_tera_on_comm_passes_high_dim() {
    // the paper's headline: on high-dimensional data FADL reaches a
    // given objective level in far fewer communication passes. Needs a
    // scale where shards are meaningfully sized (the approximations
    // degrade on toy shards — §4.7.1's P-dependence).
    let f_star = {
        let mut cfg = small_cfg("kdd2010", "tera", 1);
        cfg.scale = 2e-4;
        cfg.max_outer = 300;
        cfg.eps_g = 1e-13;
        let exp = driver::prepare(&cfg).unwrap();
        driver::run(&exp).unwrap().1.best_f()
    };
    let run = |method: &str| {
        let mut cfg = small_cfg("kdd2010", method, 8);
        cfg.scale = 2e-4;
        cfg.max_outer = 100;
        let exp = driver::prepare(&cfg).unwrap();
        driver::run(&exp).unwrap().1
    };
    let fadl = run("fadl");
    let tera = run("tera");
    // target: close 98% of the initial optimality gap (the gap is huge
    // on this near-separable set, so multiplicative f*·(1+ε) is
    // unreachable in a bounded run)
    let f0 = fadl.records[0].f.max(tera.records[0].f);
    let target = f_star + 0.02 * (f0 - f_star);
    let fadl_cost = fadl.first_reaching_f(target).map(|r| r.comm_passes);
    let tera_cost = tera.first_reaching_f(target).map(|r| r.comm_passes);
    let (Some(fc), Some(tc)) = (fadl_cost, tera_cost) else {
        panic!("a method never reached f* + 5%: fadl {fadl_cost:?} tera {tera_cost:?}");
    };
    assert!(fc < tc, "fadl {fc} passes vs tera {tc}");
}

#[test]
fn trace_json_roundtrips_through_driver() {
    let dir = std::env::temp_dir().join("fadl_integration_json");
    let path = dir.join("t.json");
    let mut cfg = small_cfg("rcv", "fadl", 2);
    cfg.out_json = Some(path.to_string_lossy().into_owned());
    cfg.max_outer = 3;
    let exp = driver::prepare(&cfg).unwrap();
    let (_, trace) = driver::run(&exp).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = fadl::util::json::parse(&text).unwrap();
    assert_eq!(
        parsed.get("f").unwrap().as_arr().unwrap().len(),
        trace.records.len()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deterministic_end_to_end() {
    // full pipeline determinism: same config ⇒ identical weights
    let cfg = small_cfg("url", "fadl", 4);
    let run = || {
        let exp = driver::prepare(&cfg).unwrap();
        driver::run(&exp).unwrap().0
    };
    let w1 = run();
    let w2 = run();
    assert_eq!(w1, w2);
    assert!(linalg::norm(&w1) > 0.0);
}

#[test]
fn gamma_shifts_the_time_balance_not_the_path() {
    // raising γ must leave iterates identical but inflate comm time —
    // the cost model is observability, not dynamics.
    let run = |gamma: f64| {
        let mut cfg = small_cfg("kdd2010", "fadl", 4);
        cfg.cost.gamma = gamma;
        cfg.max_outer = 6;
        let exp = driver::prepare(&cfg).unwrap();
        driver::run(&exp).unwrap()
    };
    let (w_lo, t_lo) = run(10.0);
    let (w_hi, t_hi) = run(1000.0);
    assert_eq!(w_lo, w_hi);
    let last_lo = t_lo.records.last().unwrap();
    let last_hi = t_hi.records.last().unwrap();
    assert_eq!(last_lo.comm_passes, last_hi.comm_passes);
    assert!(last_hi.sim_comm_secs > 10.0 * last_lo.sim_comm_secs);
}
