//! TCP transport integration: real worker OS processes must reproduce
//! the in-process transport exactly — on the star data plane (vectors
//! gathered through the driver) AND on the peer-to-peer data plane
//! (the worker ⇄ worker mesh executes the reduction plan).
//!
//! Uses the `worker` binary Cargo builds for this package
//! (`CARGO_BIN_EXE_worker`), so no self-exec fallback is involved.

use fadl::coordinator::driver;
use fadl::loss::Loss;
use fadl::net::{CombineSpec, DataPlane, Topology, VecRef};
use fadl::Config;

fn base_cfg() -> Config {
    Config {
        name: "net_tcp_test".into(),
        quick_n: 240,
        quick_m: 30,
        quick_nnz: 6,
        nodes: 2,
        max_outer: 4,
        worker_bin: env!("CARGO_BIN_EXE_worker").to_string(),
        ..Config::default()
    }
}

fn tcp_cfg(base: &Config, plane: DataPlane) -> Config {
    Config {
        transport: "tcp".into(),
        data_plane: plane,
        ..base.clone()
    }
}

fn run_with(cfg: &Config) -> fadl::metrics::Trace {
    let exp = driver::prepare(cfg).expect("prepare");
    let (_, trace) = driver::run(&exp).expect("run");
    trace
}

fn assert_traces_bitwise(
    a: &fadl::metrics::Trace,
    b: &fadl::metrics::Trace,
    label: &str,
) {
    assert_eq!(a.records.len(), b.records.len(), "{label}");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        // same worker code + same reduction schedule ⇒ bitwise equal
        assert_eq!(
            ra.f.to_bits(),
            rb.f.to_bits(),
            "{label} iter {}: {} vs {}",
            ra.iter,
            ra.f,
            rb.f
        );
        // NaN for the dual methods, identical bits either way
        assert_eq!(ra.grad_norm.to_bits(), rb.grad_norm.to_bits(), "{label}");
        // the simulated clock must be transport- and plane-independent
        assert_eq!(ra.comm_passes, rb.comm_passes, "{label}");
        assert_eq!(ra.sim_secs, rb.sim_secs, "{label}");
    }
}

#[test]
fn tcp_training_matches_inproc_bitwise_on_both_planes() {
    for topology in [
        Topology::Flat,
        Topology::Tree,
        Topology::Ring,
        Topology::HalvingDoubling,
        Topology::PipelinedTree,
    ] {
        let base = Config { topology, ..base_cfg() };
        let inproc = run_with(&Config { transport: "inproc".into(), ..base.clone() });
        let star = run_with(&tcp_cfg(&base, DataPlane::Star));
        let p2p = run_with(&tcp_cfg(&base, DataPlane::P2p));
        assert_traces_bitwise(&inproc, &star, &format!("{topology:?} star"));
        assert_traces_bitwise(&inproc, &p2p, &format!("{topology:?} p2p"));
        // measured columns: star moves control bytes only; p2p moves
        // real mesh bytes; in-process moves nothing
        let last_in = inproc.records.last().unwrap();
        let last_star = star.records.last().unwrap();
        let last_p2p = p2p.records.last().unwrap();
        assert_eq!(last_in.net_bytes, 0.0);
        assert!(last_star.net_bytes > 0.0, "star moved no bytes?");
        assert_eq!(last_star.net_data_bytes, 0.0, "star has no mesh");
        assert!(last_p2p.net_data_bytes > 0.0, "p2p mesh moved no bytes?");
        assert!(last_star.meas_phase_secs > 0.0);
    }
}

/// `topology = "auto"` over real worker processes: under p2p the
/// driver probes the live mesh at handshake time, fits per-link α–β,
/// and picks a plan family — and the trajectory still lands bit for
/// bit on an in-process run of the family it picked (whichever the
/// measurement selects). Under star there is no mesh to probe, so auto
/// resolves from the cost model's synthesized link parameters.
#[test]
fn auto_topology_over_tcp_matches_inproc_bitwise() {
    let base = base_cfg();
    let auto_cfg = Config {
        topology_auto: true,
        ..tcp_cfg(&base, DataPlane::P2p)
    };
    let exp = driver::prepare(&auto_cfg).expect("prepare");
    let chosen = exp.cluster.topology();
    let refit = fadl::net::choose_topology(
        exp.cluster.link_alpha_ns,
        exp.cluster.link_beta_ns_per_byte,
        auto_cfg.nodes,
        exp.train.m(),
    );
    assert_eq!(chosen, refit, "auto must follow the fitted α–β model");
    let (_, trace) = driver::run(&exp).expect("run");
    let reference = run_with(&Config {
        transport: "inproc".into(),
        topology: chosen,
        ..base.clone()
    });
    assert_traces_bitwise(&reference, &trace, &format!("auto→{chosen:?} p2p"));
    // the run-constant link columns record the decision
    let code = Topology::all().iter().position(|t| *t == chosen).unwrap() as f64;
    let last = trace.records.last().unwrap();
    assert_eq!(last.topology_chosen, code);
    assert!(last.link_alpha_us > 0.0, "α = {}", last.link_alpha_us);
    assert!(last.link_beta_ns_per_byte >= 0.0);
    // probe traffic is control-plane only: the scalar-driver invariant
    // and the exact mesh byte accounting hold under auto too
    for r in &trace.records {
        assert_eq!(r.driver_data_bytes, 0.0, "iter {}", r.iter);
    }
    let sched = chosen.plan(auto_cfg.nodes, auto_cfg.quick_m).mesh_bytes() as f64;
    assert!(
        (last.net_data_bytes - last.comm_passes * sched).abs() < 1e-9,
        "auto→{chosen:?}: {} mesh bytes over {} passes (1 pass = {sched})",
        last.net_data_bytes,
        last.comm_passes,
    );
    // star: no mesh, synthesized parameters, same fixed-point check
    let star_cfg = Config {
        topology_auto: true,
        ..tcp_cfg(&base, DataPlane::Star)
    };
    let star_exp = driver::prepare(&star_cfg).expect("prepare star");
    let star_chosen = star_exp.cluster.topology();
    let (_, star_trace) = driver::run(&star_exp).expect("run star");
    let star_ref = run_with(&Config {
        transport: "inproc".into(),
        topology: star_chosen,
        ..base.clone()
    });
    assert_traces_bitwise(&star_ref, &star_trace, &format!("auto→{star_chosen:?} star"));
}

#[test]
fn tcp_without_warmstart_also_matches() {
    let mut base = base_cfg();
    base.warm_start = false;
    base.max_outer = 3;
    let inproc = run_with(&Config { transport: "inproc".into(), ..base.clone() });
    for plane in DataPlane::all() {
        let tcp = run_with(&tcp_cfg(&base, plane));
        assert_eq!(
            inproc.final_f().to_bits(),
            tcp.final_f().to_bits(),
            "{}: {} vs {}",
            plane.name(),
            inproc.final_f(),
            tcp.final_f()
        );
    }
}

#[test]
fn every_method_matches_inproc_bitwise_on_both_planes() {
    // the full guarantee: every baseline — not just fadl* — trains over
    // real worker processes and reproduces the in-process trajectory
    // bit for bit on every plan family, wherever the reduction bytes
    // move (the CI parity matrix enforces the same property through
    // net_smoke at P = 4)
    for method in [
        "fadl",
        "fadl_feature",
        "tera",
        "tera-lbfgs",
        "admm",
        "cocoa",
        "ssz",
    ] {
        for topology in [
            Topology::Tree,
            Topology::Ring,
            Topology::HalvingDoubling,
            Topology::PipelinedTree,
        ] {
            let base = Config {
                method: method.into(),
                topology,
                max_outer: 3,
                ..base_cfg()
            };
            let inproc =
                run_with(&Config { transport: "inproc".into(), ..base.clone() });
            for plane in DataPlane::all() {
                let label = format!("{method} {topology:?} {}", plane.name());
                let tcp = run_with(&tcp_cfg(&base, plane));
                assert_traces_bitwise(&inproc, &tcp, &label);
                assert!(
                    tcp.records.last().unwrap().net_bytes > 0.0,
                    "{label}: tcp moved no bytes?"
                );
            }
        }
    }
}

/// The combine-plane byte assertion on [`fadl::net::Measured`]: under
/// the p2p data plane the driver executes no reduction gather — its
/// reduce-attributed traffic is zero and, with the vectors referenced
/// by register, **no m-sized payload transits the driver at all**; the
/// P part vectors move worker ⇄ worker (exactly the schedule's frame
/// bytes). Under star the same phase gathers all P part vectors
/// through the driver and broadcasts the sums back.
#[test]
fn p2p_driver_combine_traffic_is_scalar_only() {
    let nodes = 4;
    for topology in [
        Topology::Flat,
        Topology::Tree,
        Topology::Ring,
        Topology::HalvingDoubling,
        Topology::PipelinedTree,
    ] {
        let base = Config { nodes, topology, ..base_cfg() };
        let mut grads = Vec::new();
        for plane in DataPlane::all() {
            let cfg = tcp_cfg(&base, plane);
            let (train, _) = driver::build_train_split(&cfg).expect("split");
            let cluster = driver::build_cluster(&cfg, &train, None, cfg.nodes, cfg.cost)
                .expect("cluster");
            let m = cluster.m();
            let w = vec![0.01; m];
            cluster.reset_phase();
            // preload the iterate register (round-0 inline ship),
            // then measure one register-referenced grad combine
            cluster.set_reg_phase(0, &w);
            let before = cluster.measured();
            let _ = cluster.grad_combine_phase(
                Loss::SquaredHinge,
                VecRef::Reg(0),
                &CombineSpec::sum_into(1).with_dots(&[(1, 1)]),
            );
            let after = cluster.measured();
            let rx = after.bytes_rx - before.bytes_rx;
            let reduce = after.reduce_bytes - before.reduce_bytes;
            let data = after.data_bytes - before.data_bytes;
            let driver_data = after.driver_data_bytes - before.driver_data_bytes;
            let label = format!("{topology:?} {}", plane.name());
            match plane {
                DataPlane::Star => {
                    // the driver gathered all P part vectors …
                    assert_eq!(reduce, 8 * (m * nodes) as u64, "{label}");
                    assert_eq!(data, 0, "{label}: star has no mesh");
                    assert!(rx > 8 * (m * nodes) as u64, "{label}");
                    // … and shipped the sums back for the rank-side
                    // epilogue: gather + P broadcast copies
                    assert_eq!(
                        driver_data,
                        8 * (m * nodes) as u64 + 8 * (m * nodes) as u64,
                        "{label}"
                    );
                }
                DataPlane::P2p => {
                    // no m-vector of any kind transits the driver:
                    // no gather, no combined-result reply, no payload
                    assert_eq!(reduce, 0, "{label}");
                    assert_eq!(driver_data, 0, "{label}: scalar-only driver");
                    // the per-rank replies are scalar-sized
                    assert!(rx < 1024, "{label}: rx = {rx}");
                    // … and the mesh moved exactly the schedule's frames
                    assert_eq!(data, topology.plan(nodes, m).mesh_bytes(), "{label}");
                }
            }
            // the combined register is bitwise identical on both planes
            // (fetched as instrumentation, after the measurement above)
            grads.push(cluster.fetch_reg(1));
        }
        let (a, b) = (&grads[0], &grads[1]);
        assert!(
            a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{topology:?}: star and p2p reduced gradients diverged"
        );
    }
}

/// The endgame invariant of the combine plane: for ALL seven methods,
/// trained end-to-end through the real driver pipeline under
/// `data_plane = "p2p"`, **no m-sized f64 payload crosses a driver
/// link after round 0** — every trace record's cumulative
/// `driver_data_bytes` is 0. Since the held-out set became
/// worker-resident this holds WITH AUPRC instrumentation on
/// (`test_fraction` keeps its 0.2 default here): scoring is a
/// `TestAuprc` phase replying one scalar per rank, not a `FetchReg` of
/// the iterate (the end-of-run weight fetch happens after the last
/// record). Also pins the exact per-iteration mesh byte counts for the
/// combine collectives.
#[test]
fn scalar_only_driver_for_every_method_after_round_zero() {
    for method in [
        "fadl",
        "fadl_feature",
        "tera",
        "tera-lbfgs",
        "admm",
        "cocoa",
        "ssz",
    ] {
        for topology in [
            Topology::Tree,
            Topology::Ring,
            Topology::HalvingDoubling,
            Topology::PipelinedTree,
        ] {
            let cfg = Config {
                method: method.into(),
                topology,
                max_outer: 3,
                ..tcp_cfg(&base_cfg(), DataPlane::P2p)
            };
            let trace = run_with(&cfg);
            let label = format!("{method} {topology:?}");
            assert!(!trace.records.is_empty(), "{label}");
            for r in &trace.records {
                assert_eq!(
                    r.driver_data_bytes, 0.0,
                    "{label} iter {}: the driver carried an m-vector",
                    r.iter
                );
            }
            // the mesh carried every collective: at least one full
            // AllReduce's worth of schedule frames per comm pass
            let last = trace.records.last().unwrap();
            let sched_bytes = topology.plan(cfg.nodes, cfg.quick_m).mesh_bytes() as f64;
            assert!(
                (last.net_data_bytes - last.comm_passes * sched_bytes).abs() < 1e-9,
                "{label}: {} mesh bytes over {} passes (1 pass = {sched_bytes})",
                last.net_data_bytes,
                last.comm_passes,
            );
        }
    }
}

/// The engine's end-to-end determinism contract: at `threads = 4`
/// every transport/data-plane combination reproduces the `threads = 1`
/// trajectory bit for bit — the blocked kernels' fixed-order merge
/// makes intra-worker parallelism invisible to the arithmetic, on the
/// in-process transport AND on real worker processes whose pools are
/// sized by the `Setup` frame.
#[test]
fn threads_four_trajectories_bitwise_match_threads_one_three_way() {
    // large enough shards that each rank's blocking actually splits
    // (≈72k nnz per rank → several TARGET_BLOCK_NNZ blocks)
    let base = Config {
        quick_n: 6_000,
        quick_nnz: 30,
        max_outer: 3,
        ..base_cfg()
    };
    let reference = run_with(&Config {
        transport: "inproc".into(),
        threads: 1,
        ..base.clone()
    });
    let inproc4 = run_with(&Config {
        transport: "inproc".into(),
        threads: 4,
        ..base.clone()
    });
    assert_traces_bitwise(&reference, &inproc4, "inproc T=4");
    for plane in DataPlane::all() {
        let tcp4 = run_with(&Config {
            threads: 4,
            ..tcp_cfg(&base, plane)
        });
        assert_traces_bitwise(
            &reference,
            &tcp4,
            &format!("tcp-{} T=4 vs inproc T=1", plane.name()),
        );
    }
    // the new plan families compose with intra-worker parallelism: at
    // T = 4 over the mesh they land on their own T = 1 trajectory —
    // which is itself bitwise the tree trajectory (plan invariance)
    for topology in [Topology::HalvingDoubling, Topology::PipelinedTree] {
        let base_t = Config { topology, ..base.clone() };
        let ref_t = run_with(&Config {
            transport: "inproc".into(),
            threads: 1,
            ..base_t.clone()
        });
        for (ra, rb) in reference.records.iter().zip(&ref_t.records) {
            assert_eq!(ra.f.to_bits(), rb.f.to_bits(), "{topology:?} vs tree");
        }
        let tcp4 = run_with(&Config {
            threads: 4,
            ..tcp_cfg(&base_t, DataPlane::P2p)
        });
        assert_traces_bitwise(
            &ref_t,
            &tcp4,
            &format!("tcp-p2p {topology:?} T=4 vs inproc T=1"),
        );
    }
}

/// The SIMD leg of the determinism contract: the lane-chunked kernels
/// (`simd = on`, the default) and the indexed scalar kernels reproduce
/// each other bit for bit on every transport and data plane — the
/// canonical lane DAG *is* the scalar summation order, so the toggle
/// moves codegen, never arithmetic. Shards are sized to split into
/// several blocks so the packed/fused paths actually run.
#[test]
fn simd_off_trajectories_bitwise_match_simd_on_three_way() {
    let base = Config {
        quick_n: 6_000,
        quick_nnz: 30,
        max_outer: 3,
        ..base_cfg()
    };
    assert!(base.simd, "SIMD kernels default on");
    let reference = run_with(&Config { transport: "inproc".into(), ..base.clone() });
    let scalar = run_with(&Config {
        transport: "inproc".into(),
        simd: false,
        ..base.clone()
    });
    assert_traces_bitwise(&reference, &scalar, "inproc simd=off");
    for plane in DataPlane::all() {
        let tcp = run_with(&Config {
            simd: false,
            ..tcp_cfg(&base, plane)
        });
        assert_traces_bitwise(
            &reference,
            &tcp,
            &format!("tcp-{} simd=off vs inproc simd=on", plane.name()),
        );
    }
}

/// Compute/communication overlap keeps the trajectory bitwise intact:
/// streaming completed row-block partials into the mesh while later
/// blocks compute re-orders the *transport* of the partials, never
/// their accumulation (the plan pins the merge order on both ends).
/// The trace's `overlap_secs` column must witness that frames actually
/// moved before the kernels finished.
#[test]
fn overlapped_p2p_trajectories_bitwise_match_inproc() {
    for topology in [
        Topology::Tree,
        Topology::Ring,
        Topology::HalvingDoubling,
        Topology::PipelinedTree,
    ] {
        let base = Config {
            topology,
            quick_n: 6_000,
            quick_nnz: 30,
            max_outer: 3,
            ..base_cfg()
        };
        let reference =
            run_with(&Config { transport: "inproc".into(), ..base.clone() });
        let overlapped = run_with(&Config {
            overlap: true,
            ..tcp_cfg(&base, DataPlane::P2p)
        });
        assert_traces_bitwise(
            &reference,
            &overlapped,
            &format!("{topology:?} p2p overlap=on"),
        );
        let last = overlapped.records.last().unwrap();
        assert!(last.net_data_bytes > 0.0, "{topology:?}: mesh moved no bytes?");
        assert!(
            last.overlap_secs > 0.0,
            "{topology:?}: overlap enabled but no partial frame ever flushed"
        );
        // overlap must stay invisible to the star plane and the column
        let star = run_with(&Config {
            overlap: true,
            ..tcp_cfg(&base, DataPlane::Star)
        });
        assert_traces_bitwise(&reference, &star, &format!("{topology:?} star overlap=on"));
        assert_eq!(star.records.last().unwrap().overlap_secs, 0.0, "{topology:?}");
    }
}

/// The out-of-core leg of the determinism contract: with
/// `residency = "paged"` every worker pages its shard from a `.pallas`
/// cache file block-by-block through the prefetching buffer ring, and
/// the trajectory must match the all-in-RAM run bit for bit — on the
/// in-process transport AND over real worker processes on both data
/// planes, at `threads = 4` (pool claiming and prefetch racing). CoCoA
/// rides along because its dual ascent exercises the per-example row
/// cache (`examples()`), not the block kernels.
#[test]
fn paged_residency_trajectories_bitwise_match_resident_three_way() {
    use fadl::net::Residency;
    let base = Config {
        quick_n: 6_000,
        quick_nnz: 30,
        max_outer: 3,
        threads: 4,
        ..base_cfg()
    };
    // all seven methods over the acceptance leg: tcp-p2p, threads = 4
    for method in [
        "fadl",
        "fadl_feature",
        "tera",
        "tera-lbfgs",
        "admm",
        "cocoa",
        "ssz",
    ] {
        let base = Config { method: method.into(), ..base.clone() };
        let resident =
            run_with(&Config { transport: "inproc".into(), ..base.clone() });
        assert_eq!(
            resident.records.last().unwrap().page_stall_secs,
            0.0,
            "{method}: ram residency reported page stalls"
        );
        let paged = Config {
            residency: Residency::Paged,
            page_budget_mb: 1,
            ..base.clone()
        };
        let p2p = run_with(&tcp_cfg(&paged, DataPlane::P2p));
        assert_traces_bitwise(
            &resident,
            &p2p,
            &format!("{method} tcp-p2p paged vs inproc ram"),
        );
        // fadl additionally pins the star and in-process paged legs
        if method == "fadl" {
            let paged_in =
                run_with(&Config { transport: "inproc".into(), ..paged.clone() });
            assert_traces_bitwise(&resident, &paged_in, "fadl inproc paged");
            let star = run_with(&tcp_cfg(&paged, DataPlane::Star));
            assert_traces_bitwise(&resident, &star, "fadl tcp-star paged");
        }
    }
}

/// f32 reduction frames: the mesh payload halves and the trajectory
/// stays within the accuracy gate of the f64 run — close, not bitwise
/// (encode rounds to nearest-even; accumulation is still f64).
#[test]
fn f32_frames_halve_mesh_bytes_within_accuracy_gate() {
    use fadl::net::FrameEncoding;
    let base = Config {
        topology: Topology::Tree,
        test_fraction: 0.0,
        ..base_cfg()
    };
    let f64_leg = run_with(&tcp_cfg(&base, DataPlane::P2p));
    let f32_leg = run_with(&Config {
        frame_encoding: FrameEncoding::F32,
        ..tcp_cfg(&base, DataPlane::P2p)
    });
    assert_eq!(f64_leg.records.len(), f32_leg.records.len());
    for (ra, rb) in f64_leg.records.iter().zip(&f32_leg.records) {
        assert!(
            (ra.f - rb.f).abs() <= base.frame_tol,
            "iter {}: |Δf| = {:e} above frame_tol {:e}",
            ra.iter,
            (ra.f - rb.f).abs(),
            base.frame_tol
        );
    }
    // per pass: f64 moves 8·elems + 4·frames, f32 moves 4·elems +
    // 4·frames — the element payload exactly halves
    let plan = base.topology.plan(base.nodes, base.quick_m);
    let (elems, frames): (u64, u64) = (0..base.nodes)
        .map(|r| plan.rank_schedule(r))
        .map(|s| (s.send_elems() as u64, s.send_frames() as u64))
        .fold((0, 0), |(e, f), (de, df)| (e + de, f + df));
    let passes = f64_leg.records.last().unwrap().comm_passes;
    assert_eq!(
        f64_leg.records.last().unwrap().net_data_bytes,
        passes * (8 * elems + 4 * frames) as f64
    );
    assert_eq!(
        f32_leg.records.last().unwrap().net_data_bytes,
        passes * (4 * elems + 4 * frames) as f64
    );
}

/// Exact per-iteration mesh byte counts for the combine collectives:
/// FADL moves 2 AllReduces per outer iteration (gradient + direction
/// combine) and its warm start 2 more; ADMM moves exactly 1 (the
/// consensus combine).
#[test]
fn combine_collectives_have_exact_mesh_byte_counts() {
    for topology in [
        Topology::Tree,
        Topology::Ring,
        Topology::HalvingDoubling,
        Topology::PipelinedTree,
    ] {
        // fadl with warm start: record 0 sits after warm (2 passes) +
        // grad (1); every following record adds direction + grad = 2
        let cfg = Config {
            topology,
            test_fraction: 0.0,
            ..tcp_cfg(&base_cfg(), DataPlane::P2p)
        };
        let sched = topology.plan(cfg.nodes, cfg.quick_m).mesh_bytes() as f64;
        let trace = run_with(&cfg);
        assert_eq!(trace.records[0].net_data_bytes, 3.0 * sched, "{topology:?}");
        for pair in trace.records.windows(2) {
            assert_eq!(
                pair[1].net_data_bytes - pair[0].net_data_bytes,
                2.0 * sched,
                "{topology:?} iter {}",
                pair[1].iter
            );
        }
        // admm: records sit after each iteration's single consensus
        // combine (plus the warm start's 2 passes before record 0)
        let cfg = Config {
            method: "admm".into(),
            topology,
            test_fraction: 0.0,
            max_outer: 3,
            ..tcp_cfg(&base_cfg(), DataPlane::P2p)
        };
        let trace = run_with(&cfg);
        assert_eq!(trace.records[0].net_data_bytes, 3.0 * sched, "{topology:?} admm");
        for pair in trace.records.windows(2) {
            assert_eq!(
                pair[1].net_data_bytes - pair[0].net_data_bytes,
                sched,
                "{topology:?} admm iter {}",
                pair[1].iter
            );
        }
    }
}
