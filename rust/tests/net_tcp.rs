//! TCP-loopback transport integration: real worker OS processes must
//! reproduce the in-process transport exactly.
//!
//! Uses the `worker` binary Cargo builds for this package
//! (`CARGO_BIN_EXE_worker`), so no self-exec fallback is involved.

use fadl::coordinator::driver;
use fadl::net::Topology;
use fadl::Config;

fn base_cfg() -> Config {
    Config {
        name: "net_tcp_test".into(),
        quick_n: 240,
        quick_m: 30,
        quick_nnz: 6,
        nodes: 2,
        max_outer: 4,
        worker_bin: env!("CARGO_BIN_EXE_worker").to_string(),
        ..Config::default()
    }
}

fn run_with(cfg: &Config) -> fadl::metrics::Trace {
    let exp = driver::prepare(cfg).expect("prepare");
    let (_, trace) = driver::run(&exp).expect("run");
    trace
}

#[test]
fn tcp_training_matches_inproc_bitwise() {
    for topology in [Topology::Tree, Topology::Ring] {
        let inproc = run_with(&Config {
            transport: "inproc".into(),
            topology,
            ..base_cfg()
        });
        let tcp = run_with(&Config {
            transport: "tcp".into(),
            topology,
            ..base_cfg()
        });
        assert_eq!(inproc.records.len(), tcp.records.len(), "{topology:?}");
        for (a, b) in inproc.records.iter().zip(&tcp.records) {
            // same worker code + same reduction schedule ⇒ bitwise equal
            assert_eq!(
                a.f.to_bits(),
                b.f.to_bits(),
                "{topology:?} iter {}: {} vs {}",
                a.iter,
                a.f,
                b.f
            );
            assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits());
            // the simulated clock must be transport-independent
            assert_eq!(a.comm_passes, b.comm_passes);
            assert_eq!(a.sim_secs, b.sim_secs);
        }
        // measured columns: real bytes moved over TCP, none in-process
        let last_tcp = tcp.records.last().unwrap();
        let last_in = inproc.records.last().unwrap();
        assert!(last_tcp.net_bytes > 0.0, "tcp moved no bytes?");
        assert_eq!(last_in.net_bytes, 0.0);
        assert!(last_tcp.meas_phase_secs > 0.0);
    }
}

#[test]
fn tcp_without_warmstart_also_matches() {
    let mut cfg = base_cfg();
    cfg.warm_start = false;
    cfg.max_outer = 3;
    let inproc = run_with(&Config { transport: "inproc".into(), ..cfg.clone() });
    let tcp = run_with(&Config { transport: "tcp".into(), ..cfg });
    assert_eq!(
        inproc.final_f().to_bits(),
        tcp.final_f().to_bits(),
        "{} vs {}",
        inproc.final_f(),
        tcp.final_f()
    );
}

#[test]
fn every_method_matches_inproc_bitwise_over_tcp() {
    // the full-vocabulary guarantee: every baseline — not just fadl* —
    // trains over real worker processes and reproduces the in-process
    // trajectory bit for bit (the CI parity matrix enforces the same
    // property through net_smoke at P = 4)
    for method in [
        "fadl",
        "fadl_feature",
        "tera",
        "tera-lbfgs",
        "admm",
        "cocoa",
        "ssz",
    ] {
        let cfg = Config {
            method: method.into(),
            max_outer: 3,
            ..base_cfg()
        };
        let inproc = run_with(&Config {
            transport: "inproc".into(),
            ..cfg.clone()
        });
        let tcp = run_with(&Config {
            transport: "tcp".into(),
            ..cfg
        });
        assert_eq!(inproc.records.len(), tcp.records.len(), "{method}");
        for (a, b) in inproc.records.iter().zip(&tcp.records) {
            assert_eq!(
                a.f.to_bits(),
                b.f.to_bits(),
                "{method} iter {}: {} vs {}",
                a.iter,
                a.f,
                b.f
            );
            // NaN for the dual methods, identical bits either way
            assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits(), "{method}");
            // the simulated clock must be transport-independent
            assert_eq!(a.comm_passes, b.comm_passes, "{method}");
            assert_eq!(a.sim_secs, b.sim_secs, "{method}");
        }
        assert!(
            tcp.records.last().unwrap().net_bytes > 0.0,
            "{method}: tcp moved no bytes?"
        );
    }
}
