//! TCP transport integration: real worker OS processes must reproduce
//! the in-process transport exactly — on the star data plane (vectors
//! gathered through the driver) AND on the peer-to-peer data plane
//! (the worker ⇄ worker mesh executes the reduction plan).
//!
//! Uses the `worker` binary Cargo builds for this package
//! (`CARGO_BIN_EXE_worker`), so no self-exec fallback is involved.

use fadl::coordinator::driver;
use fadl::loss::Loss;
use fadl::net::{DataPlane, Topology};
use fadl::Config;

fn base_cfg() -> Config {
    Config {
        name: "net_tcp_test".into(),
        quick_n: 240,
        quick_m: 30,
        quick_nnz: 6,
        nodes: 2,
        max_outer: 4,
        worker_bin: env!("CARGO_BIN_EXE_worker").to_string(),
        ..Config::default()
    }
}

fn tcp_cfg(base: &Config, plane: DataPlane) -> Config {
    Config {
        transport: "tcp".into(),
        data_plane: plane,
        ..base.clone()
    }
}

fn run_with(cfg: &Config) -> fadl::metrics::Trace {
    let exp = driver::prepare(cfg).expect("prepare");
    let (_, trace) = driver::run(&exp).expect("run");
    trace
}

fn assert_traces_bitwise(
    a: &fadl::metrics::Trace,
    b: &fadl::metrics::Trace,
    label: &str,
) {
    assert_eq!(a.records.len(), b.records.len(), "{label}");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        // same worker code + same reduction schedule ⇒ bitwise equal
        assert_eq!(
            ra.f.to_bits(),
            rb.f.to_bits(),
            "{label} iter {}: {} vs {}",
            ra.iter,
            ra.f,
            rb.f
        );
        // NaN for the dual methods, identical bits either way
        assert_eq!(ra.grad_norm.to_bits(), rb.grad_norm.to_bits(), "{label}");
        // the simulated clock must be transport- and plane-independent
        assert_eq!(ra.comm_passes, rb.comm_passes, "{label}");
        assert_eq!(ra.sim_secs, rb.sim_secs, "{label}");
    }
}

#[test]
fn tcp_training_matches_inproc_bitwise_on_both_planes() {
    for topology in [Topology::Tree, Topology::Ring] {
        let base = Config { topology, ..base_cfg() };
        let inproc = run_with(&Config { transport: "inproc".into(), ..base.clone() });
        let star = run_with(&tcp_cfg(&base, DataPlane::Star));
        let p2p = run_with(&tcp_cfg(&base, DataPlane::P2p));
        assert_traces_bitwise(&inproc, &star, &format!("{topology:?} star"));
        assert_traces_bitwise(&inproc, &p2p, &format!("{topology:?} p2p"));
        // measured columns: star moves control bytes only; p2p moves
        // real mesh bytes; in-process moves nothing
        let last_in = inproc.records.last().unwrap();
        let last_star = star.records.last().unwrap();
        let last_p2p = p2p.records.last().unwrap();
        assert_eq!(last_in.net_bytes, 0.0);
        assert!(last_star.net_bytes > 0.0, "star moved no bytes?");
        assert_eq!(last_star.net_data_bytes, 0.0, "star has no mesh");
        assert!(last_p2p.net_data_bytes > 0.0, "p2p mesh moved no bytes?");
        assert!(last_star.meas_phase_secs > 0.0);
    }
}

#[test]
fn tcp_without_warmstart_also_matches() {
    let mut base = base_cfg();
    base.warm_start = false;
    base.max_outer = 3;
    let inproc = run_with(&Config { transport: "inproc".into(), ..base.clone() });
    for plane in DataPlane::all() {
        let tcp = run_with(&tcp_cfg(&base, plane));
        assert_eq!(
            inproc.final_f().to_bits(),
            tcp.final_f().to_bits(),
            "{}: {} vs {}",
            plane.name(),
            inproc.final_f(),
            tcp.final_f()
        );
    }
}

#[test]
fn every_method_matches_inproc_bitwise_on_both_planes() {
    // the full guarantee: every baseline — not just fadl* — trains over
    // real worker processes and reproduces the in-process trajectory
    // bit for bit on tree AND ring, wherever the reduction bytes move
    // (the CI parity matrix enforces the same property through
    // net_smoke at P = 4)
    for method in [
        "fadl",
        "fadl_feature",
        "tera",
        "tera-lbfgs",
        "admm",
        "cocoa",
        "ssz",
    ] {
        for topology in [Topology::Tree, Topology::Ring] {
            let base = Config {
                method: method.into(),
                topology,
                max_outer: 3,
                ..base_cfg()
            };
            let inproc =
                run_with(&Config { transport: "inproc".into(), ..base.clone() });
            for plane in DataPlane::all() {
                let label = format!("{method} {topology:?} {}", plane.name());
                let tcp = run_with(&tcp_cfg(&base, plane));
                assert_traces_bitwise(&inproc, &tcp, &label);
                assert!(
                    tcp.records.last().unwrap().net_bytes > 0.0,
                    "{label}: tcp moved no bytes?"
                );
            }
        }
    }
}

/// The acceptance assertion on [`fadl::net::Measured`]: under the p2p
/// data plane the driver executes no reduction gather — its
/// reduce-attributed traffic is zero and its total per-phase receive
/// traffic is O(one reduced vector + headers), while the P part
/// vectors move worker ⇄ worker (exactly the schedule's frame bytes).
/// Under star the same phase gathers all P part vectors through the
/// driver.
#[test]
fn p2p_driver_reduce_traffic_is_control_only() {
    let nodes = 4;
    for topology in [Topology::Tree, Topology::Ring] {
        let base = Config { nodes, topology, ..base_cfg() };
        let mut grads = Vec::new();
        for plane in DataPlane::all() {
            let cfg = tcp_cfg(&base, plane);
            let (train, _) = driver::build_train_split(&cfg).expect("split");
            let cluster =
                driver::build_cluster(&cfg, &train, cfg.nodes, cfg.cost).expect("cluster");
            let m = cluster.m();
            let w = vec![0.01; m];
            cluster.reset_phase();
            let before = cluster.measured();
            let (_, grad) = cluster.grad_phase(Loss::SquaredHinge, &w);
            let after = cluster.measured();
            let rx = after.bytes_rx - before.bytes_rx;
            let reduce = after.reduce_bytes - before.reduce_bytes;
            let data = after.data_bytes - before.data_bytes;
            let label = format!("{topology:?} {}", plane.name());
            match plane {
                DataPlane::Star => {
                    // the driver gathered all P part vectors
                    assert_eq!(reduce, 8 * (m * nodes) as u64, "{label}");
                    assert_eq!(data, 0, "{label}: star has no mesh");
                    assert!(rx > 8 * (m * nodes) as u64, "{label}");
                }
                DataPlane::P2p => {
                    // no m-vector gather transits the driver …
                    assert_eq!(reduce, 0, "{label}");
                    // … the driver receives one reduced vector (rank
                    // 0's reply) plus per-rank headers, not P vectors
                    assert!(rx < 8 * 2 * m as u64 + 1024, "{label}: rx = {rx}");
                    // … and the mesh moved exactly the schedule's frames
                    let plan = topology.plan(nodes, m);
                    let expected: u64 = plan
                        .rank_schedules()
                        .iter()
                        .map(|s| {
                            let sends = s
                                .ops
                                .iter()
                                .filter(|op| {
                                    matches!(
                                        op,
                                        fadl::net::topology::MeshOp::Send { .. }
                                    )
                                })
                                .count() as u64;
                            8 * s.send_elems() as u64 + 4 * sends
                        })
                        .sum();
                    assert_eq!(data, expected, "{label}");
                }
            }
            grads.push(grad);
        }
        // and the reduced gradient itself is bitwise identical
        let (a, b) = (&grads[0], &grads[1]);
        assert!(
            a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{topology:?}: star and p2p reduced gradients diverged"
        );
    }
}
