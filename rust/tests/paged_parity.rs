//! The out-of-core determinism contract: every [`ShardCompute`] kernel
//! of [`PagedShard`] must be **bitwise identical** to [`SparseShard`]
//! over the same data — for every thread count, buffer-ring size,
//! prefetch depth, and adversarial blocking (many more blocks than
//! buffers, single-row blocks, empty rows, empty shards). The blocking
//! is stored in the `.pallas` file and is a pure function of the data,
//! so any bit divergence is a real residency leak, not a re-blocking.

use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::sync::Arc;

use fadl::data::paged::PagedShard;
use fadl::data::store::{self, ShardStore};
use fadl::linalg::Csr;
use fadl::loss::Loss;
use fadl::objective::engine::{self, ComputePool};
use fadl::objective::{Shard, ShardCompute, SparseShard};
use fadl::util::proptest::{Gen, Runner};
use fadl::util::rng::Pcg64;

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "fadl-paged-parity-{}-{tag}.pallas",
        std::process::id()
    ))
}

fn random_shard(n: usize, m: usize, seed: u64) -> Shard {
    let mut rng = Pcg64::new(seed);
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            // rng.below(6) == 0 leaves the row empty on purpose
            let mut cols: Vec<u32> =
                (0..rng.below(6)).map(|_| rng.below(m) as u32).collect();
            cols.sort_unstable();
            cols.dedup();
            cols.into_iter().map(|c| (c, rng.normal() as f32)).collect()
        })
        .collect();
    let x = Csr::from_rows(m, &rows);
    let y: Vec<f64> = (0..n)
        .map(|_| if rng.below(2) == 0 { 1.0 } else { -1.0 })
        .collect();
    let c: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 2.0)).collect();
    Shard { x, y, c }
}

fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Every kernel of `paged` against `resident`, bitwise.
fn assert_kernels_bitwise(
    resident: &SparseShard,
    paged: &PagedShard,
    m: usize,
    seed: u64,
    label: &str,
) {
    assert_eq!(resident.blocks(), paged.blocks(), "{label}: blocking diverged");
    assert_eq!(resident.n(), paged.n(), "{label}");
    assert_eq!(resident.nnz(), paged.nnz(), "{label}");
    let loss = if seed % 2 == 0 { Loss::SquaredHinge } else { Loss::Logistic };
    let mut rng = Pcg64::new(seed ^ 0xA11CE);
    let w: Vec<f64> = (0..m).map(|_| 0.3 * rng.normal()).collect();
    let s: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let t = rng.range_f64(0.0, 2.0);

    let (v0, g0, z0) = resident.loss_grad(loss, &w);
    let (v1, g1, z1) = paged.loss_grad(loss, &w);
    assert_eq!(v0.to_bits(), v1.to_bits(), "{label}: loss diverged");
    assert!(bits_equal(&g0, &g1), "{label}: gradient bits diverged");
    assert!(bits_equal(&z0, &z1), "{label}: margin bits diverged");

    assert!(
        bits_equal(&resident.margins(&s), &paged.margins(&s)),
        "{label}: margins() bits diverged"
    );
    assert!(
        bits_equal(&resident.hvp(loss, &z0, &s), &paged.hvp(loss, &z1, &s)),
        "{label}: hvp bits diverged"
    );
    let (p0, q0) = resident.linesearch_eval(loss, &z0, &e_of(resident, &s), t);
    let (p1, q1) = paged.linesearch_eval(loss, &z1, &e_of(paged, &s), t);
    assert_eq!(p0.to_bits(), p1.to_bits(), "{label}: linesearch φ diverged");
    assert_eq!(q0.to_bits(), q1.to_bits(), "{label}: linesearch φ' diverged");
    assert_eq!(
        resident.feature_counts(),
        paged.feature_counts(),
        "{label}: feature counts diverged"
    );
    // the packed line-search plan (if the shard is non-empty)
    let e = e_of(resident, &s);
    match (resident.linesearch_plan(&z0, &e), paged.linesearch_plan(&z1, &e)) {
        (Some(a), Some(b)) => {
            for t in [0.0, 0.5, 1.75] {
                let (pa, qa) = a.eval(loss, t);
                let (pb, qb) = b.eval(loss, t);
                assert_eq!(pa.to_bits(), pb.to_bits(), "{label}: plan φ t={t}");
                assert_eq!(qa.to_bits(), qb.to_bits(), "{label}: plan φ' t={t}");
            }
        }
        (a, b) => assert_eq!(a.is_some(), b.is_some(), "{label}: plan presence"),
    }
}

fn e_of<S: ShardCompute + ?Sized>(s: &S, d: &[f64]) -> Vec<f64> {
    s.margins(d)
}

/// (rows, cols, target_block_nnz, seed): rows may be 0 (empty shard),
/// target 1 forces one-row blocks — far more blocks than ring buffers.
struct PagedCase;

impl Gen for PagedCase {
    type Value = (usize, usize, usize, u64);

    fn draw(&self, rng: &mut Pcg64) -> Self::Value {
        (
            rng.below(50),
            1 + rng.below(24),
            1 + rng.below(30),
            rng.next_u64(),
        )
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.0 > 0 {
            out.push((v.0 / 2, v.1, v.2, v.3));
        }
        if v.2 > 1 {
            out.push((v.0, v.1, 1, v.3));
        }
        out
    }
}

#[test]
fn paged_kernels_bitwise_equal_resident_across_blockings_and_rings() {
    Runner::new(32, 0x9A6ED).run(&PagedCase, |&(n, m, target, seed)| {
        let data = random_shard(n, m, seed);
        let blocks = engine::row_blocks_with_target(&data.x, target);
        let path = temp_path(&format!("prop-{n}-{m}-{target}-{seed:016x}"));
        store::write_shard_with_blocks(&path, &data, &blocks)
            .map_err(|e| format!("write: {e}"))?;
        let result = (|| {
            let store =
                Arc::new(ShardStore::open(&path).map_err(|e| format!("open: {e}"))?);
            if store.blocks() != blocks {
                return Err("stored blocking differs from the engine's".into());
            }
            for (threads, depth) in [(1usize, 1usize), (4, 2), (4, 5)] {
                let pool = ComputePool::new(threads);
                let resident =
                    SparseShard::with_blocking(data.clone(), target, pool.clone());
                // budget 0: ring sized from threads + depth — with
                // one-row blocks that is far fewer buffers than blocks,
                // so slots recycle many times per pass
                let paged = PagedShard::from_store(store.clone(), pool, true, 0, depth);
                let label = format!("n={n} m={m} target={target} T={threads} d={depth}");
                let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
                    assert_kernels_bitwise(&resident, &paged, m, seed, &label)
                }));
                // second pass over the same pager: the ring must reset
                // cleanly between kernels (begin_pass), and the stall
                // counter must drain
                if caught.is_ok() {
                    let _ = paged.take_page_stall_ns();
                    let w = vec![0.1; m];
                    let a = resident.loss_grad(Loss::Logistic, &w);
                    let b = paged.loss_grad(Loss::Logistic, &w);
                    if a.0.to_bits() != b.0.to_bits() || !bits_equal(&a.1, &b.1) {
                        return Err(format!("{label}: second pass diverged"));
                    }
                }
                caught.map_err(|_| format!("{label}: kernel bits diverged"))?;
            }
            Ok(())
        })();
        std::fs::remove_file(&path).ok();
        result
    });
}

#[test]
fn streaming_sinks_deliver_identical_partials_paged_and_resident() {
    use std::sync::Mutex;
    let data = random_shard(400, 24, 0xBEEF);
    let target = 40; // many blocks
    let blocks = engine::row_blocks_with_target(&data.x, target);
    assert!(blocks.len() > 4, "blocking too coarse for the test");
    let path = temp_path("streaming");
    store::write_shard_with_blocks(&path, &data, &blocks).unwrap();
    let store = Arc::new(ShardStore::open(&path).unwrap());
    let mut rng = Pcg64::new(5);
    let w: Vec<f64> = (0..24).map(|_| 0.2 * rng.normal()).collect();
    let s: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
    for threads in [1usize, 4] {
        let pool = ComputePool::new(threads);
        let resident = SparseShard::with_blocking(data.clone(), target, pool.clone());
        let paged = PagedShard::from_store(store.clone(), pool, true, 0, 2);
        assert_eq!(resident.stream_block_count(), paged.stream_block_count());
        let nb = paged.stream_block_count();
        let collect = |run: &dyn Fn(&(dyn Fn(usize, &[f64]) + Sync))| {
            let parts: Mutex<Vec<Option<Vec<f64>>>> = Mutex::new(vec![None; nb]);
            run(&|b, p: &[f64]| {
                parts.lock().unwrap()[b] = Some(p.to_vec());
            });
            parts
                .into_inner()
                .unwrap()
                .into_iter()
                .map(|p| p.expect("missing block partial"))
                .collect::<Vec<_>>()
        };
        let (_, gr, zr) = resident.loss_grad(Loss::SquaredHinge, &w);
        let pr = collect(&|sink| {
            resident.loss_grad_streaming(Loss::SquaredHinge, &w, sink);
        });
        let pp = collect(&|sink| {
            let (_, g, z) = paged.loss_grad_streaming(Loss::SquaredHinge, &w, sink);
            assert!(bits_equal(&g, &gr), "T={threads}: streamed gradient diverged");
            assert!(bits_equal(&z, &zr), "T={threads}: streamed margins diverged");
        });
        for (b, (a, c)) in pr.iter().zip(&pp).enumerate() {
            assert!(bits_equal(a, c), "T={threads}: grad partial {b} diverged");
        }
        let hr = collect(&|sink| {
            resident.hvp_streaming(Loss::SquaredHinge, &zr, &s, sink);
        });
        let hp = collect(&|sink| {
            paged.hvp_streaming(Loss::SquaredHinge, &zr, &s, sink);
        });
        for (b, (a, c)) in hr.iter().zip(&hp).enumerate() {
            assert!(bits_equal(a, c), "T={threads}: hvp partial {b} diverged");
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn paged_examples_serve_identical_rows_in_any_access_order() {
    // the example-wise methods' view (CoCoA's dual ascent, the SGD warm
    // start): random access across block boundaries thrashes the
    // one-block cache but never changes a bit
    let data = random_shard(300, 20, 0x5EED);
    let blocks = engine::row_blocks_with_target(&data.x, 25);
    assert!(blocks.len() > 3);
    let path = temp_path("examples");
    store::write_shard_with_blocks(&path, &data, &blocks).unwrap();
    let paged = PagedShard::open(&path, ComputePool::serial(), true, 0, 1).unwrap();
    let resident = SparseShard::new(data.clone());
    let rex = resident.examples().expect("resident rows");
    let pex = paged.examples().expect("paged rows");
    assert_eq!(rex.n(), pex.n());
    let mut rng = Pcg64::new(42);
    let w: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
    let mut acc_r = vec![0.0f64; 20];
    let mut acc_p = vec![0.0f64; 20];
    for _ in 0..600 {
        let i = rng.below(300);
        assert_eq!(rex.y(i), pex.y(i), "row {i}");
        assert_eq!(rex.c(i).to_bits(), pex.c(i).to_bits(), "row {i}");
        assert_eq!(
            rex.row_dot(i, &w).to_bits(),
            pex.row_dot(i, &w).to_bits(),
            "row {i}: dot diverged"
        );
        assert_eq!(
            rex.row_norm_sq(i).to_bits(),
            pex.row_norm_sq(i).to_bits(),
            "row {i}: ‖x‖² diverged"
        );
        rex.row_axpy(i, 0.125, &mut acc_r);
        pex.row_axpy(i, 0.125, &mut acc_p);
    }
    assert!(bits_equal(&acc_r, &acc_p), "axpy accumulation diverged");
    std::fs::remove_file(&path).ok();
}

#[test]
fn single_buffer_ring_still_completes_and_matches() {
    // nb = 1 clamps the ring to one buffer; a multi-block shard with a
    // tiny want (serial pool + depth 1 → 2 buffers against 10+ blocks)
    // exercises maximal recycling under the deadlock-freedom argument
    let data = random_shard(200, 16, 0xD00D);
    let blocks = engine::row_blocks_with_target(&data.x, 30);
    let path = temp_path("ring");
    store::write_shard_with_blocks(&path, &data, &blocks).unwrap();
    let resident = SparseShard::with_blocking(data.clone(), 30, ComputePool::serial());
    let paged = PagedShard::open(&path, ComputePool::serial(), true, 0, 1).unwrap();
    assert_eq!(paged.page_buffers(), 2usize.min(blocks.len().max(1)));
    assert_kernels_bitwise(&resident, &paged, 16, 0xD00D, "single-buffer");
    // the stall counter drains to zero once taken
    let _ = paged.take_page_stall_ns();
    assert_eq!(paged.take_page_stall_ns(), 0);
    std::fs::remove_file(&path).ok();
}

#[test]
fn mid_file_corruption_fails_the_kernel_loudly() {
    // a flipped payload bit passes open() (the block table is clean)
    // but must abort the first kernel that pages the damaged block —
    // never train on silently corrupted rows
    let data = random_shard(250, 16, 0xC0DE);
    let blocks = engine::row_blocks_with_target(&data.x, 50);
    assert!(blocks.len() > 1);
    let path = temp_path("corrupt");
    store::write_shard_with_blocks(&path, &data, &blocks).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    {
        let store = ShardStore::open(&path).unwrap();
        let victim = store.table.len() / 2;
        let off =
            store.table[victim].offset as usize + store.table[victim].len as usize / 2;
        bytes[off] ^= 0x08;
    }
    std::fs::write(&path, &bytes).unwrap();
    let paged = PagedShard::open(&path, ComputePool::serial(), true, 0, 1).unwrap();
    let w = vec![0.1; 16];
    let out = std::panic::catch_unwind(AssertUnwindSafe(|| paged.loss_grad(Loss::Logistic, &w)));
    assert!(out.is_err(), "corrupted block fed a kernel");
    std::fs::remove_file(&path).ok();
}

#[test]
fn empty_shard_pages_to_empty_results() {
    let data = Shard { x: Csr::from_rows(8, &[]), y: vec![], c: vec![] };
    let path = temp_path("empty");
    store::write_shard(&path, &data).unwrap();
    let paged = PagedShard::open(&path, ComputePool::new(2), true, 0, 2).unwrap();
    assert_eq!(paged.n(), 0);
    assert_eq!(paged.stream_block_count(), 0);
    let w = vec![0.5; 8];
    let (v, g, z) = paged.loss_grad(Loss::Logistic, &w);
    assert_eq!(v, 0.0);
    assert_eq!(g, vec![0.0; 8]);
    assert!(z.is_empty());
    assert!(paged.margins(&w).is_empty());
    assert_eq!(paged.feature_counts(), vec![0u32; 8]);
    std::fs::remove_file(&path).ok();
}
