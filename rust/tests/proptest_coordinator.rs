//! Property-based tests (in-repo shrinking harness, DESIGN.md §8) on
//! the coordinator invariants: routing (partitioning), batching
//! (AllReduce/aggregation), and state management (objective
//! consistency, descent geometry).

use fadl::cluster::{Cluster, CostModel};
use fadl::data::partition::{ExamplePartition, Strategy};
use fadl::data::synth;
use fadl::linalg;
use fadl::loss::Loss;
use fadl::metrics::auprc::auprc;
use fadl::objective::{Objective, Shard, ShardCompute, SparseShard};
use fadl::util::proptest::{Gen, Pair, Runner, UsizeRange, VecF64};
use fadl::util::rng::Pcg64;

fn cluster_over(ds: &fadl::data::Dataset, p: usize, strategy: Strategy) -> Cluster {
    let part = ExamplePartition::build(ds.n(), p, strategy, 13);
    let workers: Vec<Box<dyn ShardCompute>> = (0..p)
        .map(|i| {
            Box::new(SparseShard::new(Shard::from_dataset(
                ds,
                &part.assignments[i],
                &part.weights[i],
            ))) as Box<dyn ShardCompute>
        })
        .collect();
    Cluster::new(workers, CostModel::default())
}

#[test]
fn prop_partition_routes_every_example_once() {
    // routing invariant: for any (n, p, strategy) the partition is a
    // true partition — every example on exactly one node, weights sum n
    let gen = Pair(UsizeRange(1, 500), UsizeRange(1, 64));
    Runner::new(128, 0xA).run(&gen, |&(n, p)| {
        for strategy in [Strategy::Contiguous, Strategy::RoundRobin, Strategy::Random] {
            let part = ExamplePartition::build(n, p, strategy, 7);
            part.validate(n, 1).map_err(|e| format!("{strategy:?}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_resampling_preserves_objective_weight() {
    let gen = Pair(UsizeRange(1, 200), UsizeRange(2, 16));
    Runner::new(64, 0xB).run(&gen, |&(n, p)| {
        let repl = 2.min(p);
        let part = ExamplePartition::build_resampled(n, p, repl, 3);
        part.validate(n, repl)?;
        if (part.total_weight() - n as f64).abs() > 1e-6 {
            return Err(format!("total weight {}", part.total_weight()));
        }
        Ok(())
    });
}

#[test]
fn prop_allreduce_equals_naive_sum() {
    // batching invariant: the binary-tree AllReduce must agree with the
    // naive sum to floating-point reassociation tolerance
    let gen = Pair(UsizeRange(1, 24), UsizeRange(1, 40));
    Runner::new(64, 0xC).run(&gen, |&(p, m)| {
        let ds = synth::quick((p * 3).max(4), 8, 3, 1);
        let cluster = cluster_over(&ds, p, Strategy::Contiguous);
        let mut rng = Pcg64::new((p * 1000 + m) as u64);
        let parts: Vec<Vec<f64>> = (0..p)
            .map(|_| (0..m).map(|_| rng.normal()).collect())
            .collect();
        let naive: Vec<f64> = (0..m)
            .map(|j| parts.iter().map(|v| v[j]).sum())
            .collect();
        let tree = cluster.allreduce(parts);
        for j in 0..m {
            if (tree[j] - naive[j]).abs() > 1e-9 * naive[j].abs().max(1.0) {
                return Err(format!("coord {j}: {} vs {}", tree[j], naive[j]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_gradient_matches_single_machine_for_any_p() {
    // state-management invariant: the distributed gradient pass is
    // exactly the single-machine gradient for every partitioning
    let gen = Pair(UsizeRange(1, 16), UsizeRange(0, 2));
    Runner::new(32, 0xD).run(&gen, |&(p, strat)| {
        let strategy = [Strategy::Contiguous, Strategy::RoundRobin, Strategy::Random][strat];
        let ds = synth::quick(120, 30, 8, 5);
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let whole = SparseShard::new(Shard::whole(&ds));
        let mut rng = Pcg64::new(p as u64);
        let w: Vec<f64> = (0..30).map(|_| 0.2 * rng.normal()).collect();
        let (want_f, want_g) = obj.eval(&[&whole], &w);
        let cluster = cluster_over(&ds, p, strategy);
        let (loss_sum, mut g, _, _) = cluster.gradient_pass(obj.loss, &w);
        obj.finish_grad(&w, &mut g);
        if (obj.value_from(&w, loss_sum) - want_f).abs() > 1e-8 * want_f.abs().max(1.0) {
            return Err(format!("value mismatch p={p}"));
        }
        for j in 0..30 {
            if (g[j] - want_g[j]).abs() > 1e-8 {
                return Err(format!("grad[{j}] p={p}: {} vs {}", g[j], want_g[j]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_linesearch_phi_matches_direct_evaluation() {
    // cached-margin line search ≡ full re-evaluation at w + t·d
    let gen = Pair(UsizeRange(1, 8), VecF64 { min_len: 1, max_len: 1, lo: 0.0, hi: 4.0 });
    Runner::new(32, 0xE).run(&gen, |(p, ts)| {
        let t = ts[0];
        let ds = synth::quick(80, 20, 6, 9);
        let obj = Objective::new(1e-3, Loss::SquaredHinge);
        let cluster = cluster_over(&ds, *p, Strategy::Contiguous);
        let mut rng = Pcg64::new(*p as u64 + 77);
        let w: Vec<f64> = (0..20).map(|_| 0.1 * rng.normal()).collect();
        let d: Vec<f64> = (0..20).map(|_| 0.1 * rng.normal()).collect();
        let (_, _, margins, _) = cluster.gradient_pass(obj.loss, &w);
        let dirs = cluster.margins_pass(&d);
        let (phi, _) = cluster.linesearch_eval(obj.loss, &margins, &dirs, t);
        let mut wt = w.clone();
        linalg::axpy(t, &d, &mut wt);
        let direct = cluster.loss_pass(obj.loss, &wt);
        if (phi - direct).abs() > 1e-8 * direct.abs().max(1.0) {
            return Err(format!("t={t}: {phi} vs {direct}"));
        }
        Ok(())
    });
}

#[test]
fn prop_fadl_direction_is_descent() {
    // Lemma 5 geometry: the combined FADL direction satisfies
    // −g·d > 0 for any partition count and any anchor
    let gen = Pair(UsizeRange(1, 8), UsizeRange(0, 10_000));
    Runner::new(24, 0xF).run(&gen, |&(p, seed)| {
        use fadl::approx::{self, ApproxKind};
        use fadl::optim::{tron::Tron, InnerOptimizer};
        let ds = synth::quick(160, 24, 6, 21);
        let obj = Objective::new(1e-2, Loss::SquaredHinge);
        let cluster = cluster_over(&ds, p, Strategy::Contiguous);
        let mut rng = Pcg64::new(seed as u64);
        let w: Vec<f64> = (0..24).map(|_| 0.3 * rng.normal()).collect();
        let (_, data_grad, margins, locals) = cluster.gradient_pass(obj.loss, &w);
        let mut g = data_grad;
        obj.finish_grad(&w, &mut g);
        if linalg::norm(&g) < 1e-10 {
            return Ok(()); // already optimal: no direction needed
        }
        let mut d = vec![0.0; 24];
        for node in 0..p {
            let ctx = approx::ApproxContext {
                shard: cluster.workers()[node].as_ref(),
                loss: obj.loss,
                lambda: obj.lambda,
                p_nodes: p as f64,
                anchor: w.clone(),
                full_grad: g.clone(),
                local_grad: locals[node].clone(),
                anchor_margins: margins[node].clone(),
            };
            let mut fp = approx::build(ApproxKind::Quadratic, ctx, None);
            let res = Tron::default().minimize(fp.as_mut(), 10);
            for j in 0..24 {
                d[j] += (res.w[j] - w[j]) / p as f64;
            }
        }
        let gd = linalg::dot(&g, &d);
        if gd >= 0.0 {
            return Err(format!("non-descent: g·d = {gd}"));
        }
        Ok(())
    });
}

#[test]
fn prop_auprc_bounded_and_order_invariant() {
    let gen = VecF64 {
        min_len: 2,
        max_len: 60,
        lo: -1.0,
        hi: 1.0,
    };
    Runner::new(128, 0x10).run(&gen, |scores| {
        let mut rng = Pcg64::new(scores.len() as u64);
        let labels: Vec<f64> = scores.iter().map(|_| rng.label(0.5)).collect();
        let v = auprc(scores, &labels);
        if !(0.0..=1.0).contains(&v) {
            return Err(format!("auprc {v} out of [0,1]"));
        }
        // permuting (score, label) pairs must not change the value
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        rng.shuffle(&mut idx);
        let s2: Vec<f64> = idx.iter().map(|&i| scores[i]).collect();
        let l2: Vec<f64> = idx.iter().map(|&i| labels[i]).collect();
        let v2 = auprc(&s2, &l2);
        if (v - v2).abs() > 1e-12 {
            return Err(format!("permutation changed auprc: {v} vs {v2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cost_model_monotone() {
    // more nodes / bigger vectors never make a communication round
    // cheaper (non-pipelined tree)
    let gen = Pair(UsizeRange(2, 512), UsizeRange(1, 1_000_000));
    Runner::new(128, 0x11).run(&gen, |&(p, m)| {
        let c = CostModel::default();
        if c.allreduce_units(m, p) < c.allreduce_units(m, p / 2 + 1) - 1e-9 {
            return Err("allreduce cheaper with more nodes".into());
        }
        if m > 1 && c.allreduce_units(m, p) < c.allreduce_units(m - 1, p) {
            return Err("allreduce cheaper with bigger vector".into());
        }
        Ok(())
    });
}

#[test]
fn prop_clock_deltas_are_additive() {
    let gen = VecF64 {
        min_len: 1,
        max_len: 20,
        lo: 0.0,
        hi: 1e6,
    };
    Runner::new(64, 0x12).run(&gen, |units| {
        let mut clock = fadl::cluster::SimClock::default();
        let mut total = 0.0;
        for &u in units {
            clock.comm_pass(u);
            total += u;
        }
        if (clock.comm_units - total).abs() > 1e-6 * total.max(1.0) {
            return Err(format!("{} vs {total}", clock.comm_units));
        }
        if clock.comm_passes != units.len() as f64 {
            return Err("pass count mismatch".into());
        }
        Ok(())
    });
}
