//! Engine determinism properties: the blocked, pool-parallel
//! `ShardCompute` kernels must be **bitwise identical** to the serial
//! reference for every thread count, across adversarial blockings —
//! more blocks than threads, fewer blocks than threads (n < T), single
//! block (T = 1 / tiny shards), single-row blocks, empty rows, empty
//! shards. The blocking is held fixed per case (it is a pure function
//! of the data), so any bit divergence is a real scheduling leak.

use fadl::linalg::Csr;
use fadl::loss::Loss;
use fadl::objective::engine::ComputePool;
use fadl::objective::{Shard, ShardCompute, SparseShard};
use fadl::util::proptest::{Gen, Runner};
use fadl::util::rng::Pcg64;

/// (rows, cols, target_block_nnz, seed) — rows may be 0 (empty shard)
/// and target 1 forces one-row blocks.
struct EngineCase;

impl Gen for EngineCase {
    type Value = (usize, usize, usize, u64);

    fn draw(&self, rng: &mut Pcg64) -> Self::Value {
        (
            rng.below(40),
            1 + rng.below(24),
            1 + rng.below(40),
            rng.next_u64(),
        )
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.0 > 0 {
            out.push((v.0 / 2, v.1, v.2, v.3));
        }
        if v.2 > 1 {
            out.push((v.0, v.1, 1, v.3));
        }
        out
    }
}

fn random_shard(n: usize, m: usize, seed: u64) -> Shard {
    let mut rng = Pcg64::new(seed);
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            // rng.below(6) == 0 leaves the row empty on purpose
            (0..rng.below(6))
                .map(|_| (rng.below(m) as u32, rng.normal() as f32))
                .collect()
        })
        .collect();
    let x = Csr::from_rows(m, &rows);
    let y: Vec<f64> = (0..n)
        .map(|_| if rng.below(2) == 0 { 1.0 } else { -1.0 })
        .collect();
    let c: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 2.0)).collect();
    Shard { x, y, c }
}

fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn blocked_kernels_bitwise_equal_across_thread_counts() {
    Runner::new(48, 0xE61E).run(&EngineCase, |&(n, m, target, seed)| {
        let data = random_shard(n, m, seed);
        let loss = if seed % 2 == 0 { Loss::SquaredHinge } else { Loss::Logistic };
        let mut rng = Pcg64::new(seed ^ 0x77);
        let w: Vec<f64> = (0..m).map(|_| 0.3 * rng.normal()).collect();
        let s: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let t = rng.range_f64(0.0, 2.0);

        let serial = SparseShard::with_blocking(data.clone(), target, ComputePool::serial());
        let (v0, g0, z0) = serial.loss_grad(loss, &w);
        let e0 = serial.margins(&s);
        let h0 = serial.hvp(loss, &z0, &s);
        let (p0, q0) = serial.linesearch_eval(loss, &z0, &e0, t);

        for threads in [2usize, 3, 8] {
            let shard =
                SparseShard::with_blocking(data.clone(), target, ComputePool::new(threads));
            if shard.blocks() != serial.blocks() {
                return Err(format!(
                    "blocking depends on the pool: {:?} vs {:?}",
                    shard.blocks(),
                    serial.blocks()
                ));
            }
            let (v, g, z) = shard.loss_grad(loss, &w);
            if v.to_bits() != v0.to_bits() {
                return Err(format!("T={threads}: loss {v} != {v0}"));
            }
            if !bits_equal(&g, &g0) {
                return Err(format!("T={threads}: gradient bits diverged"));
            }
            if !bits_equal(&z, &z0) {
                return Err(format!("T={threads}: margin bits diverged"));
            }
            if !bits_equal(&shard.margins(&s), &e0) {
                return Err(format!("T={threads}: margins() bits diverged"));
            }
            if !bits_equal(&shard.hvp(loss, &z, &s), &h0) {
                return Err(format!("T={threads}: hvp bits diverged"));
            }
            let (p, q) = shard.linesearch_eval(loss, &z, &e0, t);
            if p.to_bits() != p0.to_bits() || q.to_bits() != q0.to_bits() {
                return Err(format!("T={threads}: linesearch bits diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn packed_linesearch_plan_bitwise_equals_plain_eval() {
    Runner::new(48, 0x9ACD).run(&EngineCase, |&(n, m, target, seed)| {
        let data = random_shard(n, m, seed);
        let loss = if seed % 2 == 0 { Loss::SquaredHinge } else { Loss::Logistic };
        let mut rng = Pcg64::new(seed ^ 0x3131);
        let w: Vec<f64> = (0..m).map(|_| 0.3 * rng.normal()).collect();
        let d: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        for threads in [1usize, 4] {
            let shard =
                SparseShard::with_blocking(data.clone(), target, ComputePool::new(threads));
            let (_, _, z) = shard.loss_grad(loss, &w);
            let e = shard.margins(&d);
            let Some(plan) = shard.linesearch_plan(&z, &e) else {
                return Err("sparse backend refused to build a plan".into());
            };
            if plan.n() != n {
                return Err(format!("plan packed {} of {n} rows", plan.n()));
            }
            // the same plan serves every trial step of the search
            for _ in 0..4 {
                let t = rng.range_f64(-1.0, 3.0);
                let (pp, pd) = plan.eval(loss, t);
                let (wp, wd) = shard.linesearch_eval(loss, &z, &e, t);
                if pp.to_bits() != wp.to_bits() || pd.to_bits() != wd.to_bits() {
                    return Err(format!(
                        "T={threads} t={t}: packed ({pp}, {pd}) != plain ({wp}, {wd})"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Rows seeded with adversarial IEEE-754 values: negative zero,
/// f32 subnormals, magnitudes near overflow/underflow — the inputs
/// where a reassociated SIMD reduction would betray itself first.
fn adversarial_shard(n: usize, m: usize, seed: u64) -> Shard {
    const SPECIALS: [f32; 8] =
        [-0.0, 1.0e-40, -1.0e-40, f32::MIN_POSITIVE, -f32::MIN_POSITIVE, 1.0e30, -1.0e-30, 2.5];
    let mut rng = Pcg64::new(seed);
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            // rng.below(6) == 0 leaves the row empty on purpose
            (0..rng.below(6))
                .map(|_| {
                    let v = if rng.below(3) == 0 {
                        SPECIALS[rng.below(SPECIALS.len())]
                    } else {
                        rng.normal() as f32
                    };
                    (rng.below(m) as u32, v)
                })
                .collect()
        })
        .collect();
    let x = Csr::from_rows(m, &rows);
    let y: Vec<f64> = (0..n)
        .map(|_| if rng.below(2) == 0 { 1.0 } else { -1.0 })
        .collect();
    let c: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 2.0)).collect();
    Shard { x, y, c }
}

#[test]
fn simd_kernels_bitwise_equal_scalar_on_adversarial_shards() {
    // the SIMD contract: the lane-chunked kernels and the indexed
    // scalar kernels are the same summation DAG, so every output bit
    // matches — including over subnormals, −0.0, empty rows, rows
    // shorter than a lane (n < LANES), and one-row blocks (target 1)
    Runner::new(48, 0x51D3).run(&EngineCase, |&(n, m, target, seed)| {
        let data = adversarial_shard(n, m, seed);
        let loss = if seed % 2 == 0 { Loss::SquaredHinge } else { Loss::Logistic };
        let mut rng = Pcg64::new(seed ^ 0xAB);
        // weights get their own adversarial f64s: a subnormal scale and
        // a negative zero land in every drawn vector
        let mut draw_vec = |len: usize| -> Vec<f64> {
            let mut v: Vec<f64> = (0..len).map(|_| 0.3 * rng.normal()).collect();
            if len > 1 {
                v[0] = -0.0;
                v[len / 2] = 1.0e-310;
            }
            v
        };
        let w = draw_vec(m);
        let s = draw_vec(m);
        let t = rng.range_f64(0.0, 2.0);
        for threads in [1usize, 3] {
            let mut simd =
                SparseShard::with_blocking(data.clone(), target, ComputePool::new(threads));
            simd.set_simd(true);
            let mut scalar =
                SparseShard::with_blocking(data.clone(), target, ComputePool::new(threads));
            scalar.set_simd(false);
            let (va, ga, za) = simd.loss_grad(loss, &w);
            let (vb, gb, zb) = scalar.loss_grad(loss, &w);
            if va.to_bits() != vb.to_bits() {
                return Err(format!("T={threads}: loss {va} != {vb}"));
            }
            if !bits_equal(&ga, &gb) || !bits_equal(&za, &zb) {
                return Err(format!("T={threads}: loss_grad bits diverged"));
            }
            if !bits_equal(&simd.margins(&s), &scalar.margins(&s)) {
                return Err(format!("T={threads}: margins bits diverged"));
            }
            if !bits_equal(&simd.hvp(loss, &za, &s), &scalar.hvp(loss, &zb, &s)) {
                return Err(format!("T={threads}: hvp bits diverged"));
            }
            let e = simd.margins(&s);
            let (pa, qa) = simd.linesearch_eval(loss, &za, &e, t);
            let (pb, qb) = scalar.linesearch_eval(loss, &zb, &e, t);
            if pa.to_bits() != pb.to_bits() || qa.to_bits() != qb.to_bits() {
                return Err(format!("T={threads}: linesearch bits diverged"));
            }
            let (plan_a, plan_b) = (
                simd.linesearch_plan(&za, &e).ok_or("simd plan refused")?,
                scalar.linesearch_plan(&zb, &e).ok_or("scalar plan refused")?,
            );
            let (ra, da) = plan_a.eval(loss, t);
            let (rb, db) = plan_b.eval(loss, t);
            if ra.to_bits() != rb.to_bits() || da.to_bits() != db.to_bits() {
                return Err(format!("T={threads}: packed linesearch bits diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn default_blocking_single_block_matches_seed_arithmetic() {
    // a shard under TARGET_BLOCK_NNZ has exactly one block, whose
    // fused pass reproduces the historical unblocked loop bit for bit
    // (value fold seeds from block 0; gradient merge copies block 0) —
    // pinned here by comparing against an explicit single-block shard
    let data = random_shard(30, 12, 7);
    let auto = SparseShard::new(data.clone());
    assert_eq!(auto.blocks().len(), 1);
    let one = SparseShard::with_blocking(data, usize::MAX, ComputePool::new(4));
    let w = vec![0.1; 12];
    let (va, ga, za) = auto.loss_grad(Loss::SquaredHinge, &w);
    let (vo, go, zo) = one.loss_grad(Loss::SquaredHinge, &w);
    assert_eq!(va.to_bits(), vo.to_bits());
    assert!(bits_equal(&ga, &go));
    assert!(bits_equal(&za, &zo));
}

#[test]
fn empty_shard_kernels_are_well_defined() {
    let data = random_shard(0, 5, 1);
    for threads in [1usize, 4] {
        let shard = SparseShard::with_blocking(data.clone(), 4, ComputePool::new(threads));
        let (v, g, z) = shard.loss_grad(Loss::Logistic, &[0.0; 5]);
        assert_eq!(v, 0.0);
        assert_eq!(g, vec![0.0; 5]);
        assert!(z.is_empty());
        assert!(shard.margins(&[0.0; 5]).is_empty());
        assert_eq!(shard.hvp(Loss::Logistic, &z, &[0.0; 5]), vec![0.0; 5]);
        assert_eq!(shard.linesearch_eval(Loss::Logistic, &z, &z, 0.5), (0.0, 0.0));
        let plan = shard.linesearch_plan(&z, &z).expect("empty plan is fine");
        assert_eq!(plan.eval(Loss::Logistic, 0.5), (0.0, 0.0));
    }
}
