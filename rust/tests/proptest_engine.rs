//! Engine determinism properties: the blocked, pool-parallel
//! `ShardCompute` kernels must be **bitwise identical** to the serial
//! reference for every thread count, across adversarial blockings —
//! more blocks than threads, fewer blocks than threads (n < T), single
//! block (T = 1 / tiny shards), single-row blocks, empty rows, empty
//! shards. The blocking is held fixed per case (it is a pure function
//! of the data), so any bit divergence is a real scheduling leak.

use fadl::linalg::Csr;
use fadl::loss::Loss;
use fadl::objective::engine::ComputePool;
use fadl::objective::{Shard, ShardCompute, SparseShard};
use fadl::util::proptest::{Gen, Runner};
use fadl::util::rng::Pcg64;

/// (rows, cols, target_block_nnz, seed) — rows may be 0 (empty shard)
/// and target 1 forces one-row blocks.
struct EngineCase;

impl Gen for EngineCase {
    type Value = (usize, usize, usize, u64);

    fn draw(&self, rng: &mut Pcg64) -> Self::Value {
        (
            rng.below(40),
            1 + rng.below(24),
            1 + rng.below(40),
            rng.next_u64(),
        )
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.0 > 0 {
            out.push((v.0 / 2, v.1, v.2, v.3));
        }
        if v.2 > 1 {
            out.push((v.0, v.1, 1, v.3));
        }
        out
    }
}

fn random_shard(n: usize, m: usize, seed: u64) -> Shard {
    let mut rng = Pcg64::new(seed);
    let rows: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| {
            // rng.below(6) == 0 leaves the row empty on purpose
            (0..rng.below(6))
                .map(|_| (rng.below(m) as u32, rng.normal() as f32))
                .collect()
        })
        .collect();
    let x = Csr::from_rows(m, &rows);
    let y: Vec<f64> = (0..n)
        .map(|_| if rng.below(2) == 0 { 1.0 } else { -1.0 })
        .collect();
    let c: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 2.0)).collect();
    Shard { x, y, c }
}

fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn blocked_kernels_bitwise_equal_across_thread_counts() {
    Runner::new(48, 0xE61E).run(&EngineCase, |&(n, m, target, seed)| {
        let data = random_shard(n, m, seed);
        let loss = if seed % 2 == 0 { Loss::SquaredHinge } else { Loss::Logistic };
        let mut rng = Pcg64::new(seed ^ 0x77);
        let w: Vec<f64> = (0..m).map(|_| 0.3 * rng.normal()).collect();
        let s: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let t = rng.range_f64(0.0, 2.0);

        let serial = SparseShard::with_blocking(data.clone(), target, ComputePool::serial());
        let (v0, g0, z0) = serial.loss_grad(loss, &w);
        let e0 = serial.margins(&s);
        let h0 = serial.hvp(loss, &z0, &s);
        let (p0, q0) = serial.linesearch_eval(loss, &z0, &e0, t);

        for threads in [2usize, 3, 8] {
            let shard =
                SparseShard::with_blocking(data.clone(), target, ComputePool::new(threads));
            if shard.blocks() != serial.blocks() {
                return Err(format!(
                    "blocking depends on the pool: {:?} vs {:?}",
                    shard.blocks(),
                    serial.blocks()
                ));
            }
            let (v, g, z) = shard.loss_grad(loss, &w);
            if v.to_bits() != v0.to_bits() {
                return Err(format!("T={threads}: loss {v} != {v0}"));
            }
            if !bits_equal(&g, &g0) {
                return Err(format!("T={threads}: gradient bits diverged"));
            }
            if !bits_equal(&z, &z0) {
                return Err(format!("T={threads}: margin bits diverged"));
            }
            if !bits_equal(&shard.margins(&s), &e0) {
                return Err(format!("T={threads}: margins() bits diverged"));
            }
            if !bits_equal(&shard.hvp(loss, &z, &s), &h0) {
                return Err(format!("T={threads}: hvp bits diverged"));
            }
            let (p, q) = shard.linesearch_eval(loss, &z, &e0, t);
            if p.to_bits() != p0.to_bits() || q.to_bits() != q0.to_bits() {
                return Err(format!("T={threads}: linesearch bits diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn packed_linesearch_plan_bitwise_equals_plain_eval() {
    Runner::new(48, 0x9ACD).run(&EngineCase, |&(n, m, target, seed)| {
        let data = random_shard(n, m, seed);
        let loss = if seed % 2 == 0 { Loss::SquaredHinge } else { Loss::Logistic };
        let mut rng = Pcg64::new(seed ^ 0x3131);
        let w: Vec<f64> = (0..m).map(|_| 0.3 * rng.normal()).collect();
        let d: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        for threads in [1usize, 4] {
            let shard =
                SparseShard::with_blocking(data.clone(), target, ComputePool::new(threads));
            let (_, _, z) = shard.loss_grad(loss, &w);
            let e = shard.margins(&d);
            let Some(plan) = shard.linesearch_plan(&z, &e) else {
                return Err("sparse backend refused to build a plan".into());
            };
            if plan.n() != n {
                return Err(format!("plan packed {} of {n} rows", plan.n()));
            }
            // the same plan serves every trial step of the search
            for _ in 0..4 {
                let t = rng.range_f64(-1.0, 3.0);
                let (pp, pd) = plan.eval(loss, t);
                let (wp, wd) = shard.linesearch_eval(loss, &z, &e, t);
                if pp.to_bits() != wp.to_bits() || pd.to_bits() != wd.to_bits() {
                    return Err(format!(
                        "T={threads} t={t}: packed ({pp}, {pd}) != plain ({wp}, {wd})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn default_blocking_single_block_matches_seed_arithmetic() {
    // a shard under TARGET_BLOCK_NNZ has exactly one block, whose
    // fused pass reproduces the historical unblocked loop bit for bit
    // (value fold seeds from block 0; gradient merge copies block 0) —
    // pinned here by comparing against an explicit single-block shard
    let data = random_shard(30, 12, 7);
    let auto = SparseShard::new(data.clone());
    assert_eq!(auto.blocks().len(), 1);
    let one = SparseShard::with_blocking(data, usize::MAX, ComputePool::new(4));
    let w = vec![0.1; 12];
    let (va, ga, za) = auto.loss_grad(Loss::SquaredHinge, &w);
    let (vo, go, zo) = one.loss_grad(Loss::SquaredHinge, &w);
    assert_eq!(va.to_bits(), vo.to_bits());
    assert!(bits_equal(&ga, &go));
    assert!(bits_equal(&za, &zo));
}

#[test]
fn empty_shard_kernels_are_well_defined() {
    let data = random_shard(0, 5, 1);
    for threads in [1usize, 4] {
        let shard = SparseShard::with_blocking(data.clone(), 4, ComputePool::new(threads));
        let (v, g, z) = shard.loss_grad(Loss::Logistic, &[0.0; 5]);
        assert_eq!(v, 0.0);
        assert_eq!(g, vec![0.0; 5]);
        assert!(z.is_empty());
        assert!(shard.margins(&[0.0; 5]).is_empty());
        assert_eq!(shard.hvp(Loss::Logistic, &z, &[0.0; 5]), vec![0.0; 5]);
        assert_eq!(shard.linesearch_eval(Loss::Logistic, &z, &z, 0.5), (0.0, 0.0));
        let plan = shard.linesearch_plan(&z, &z).expect("empty plan is fine");
        assert_eq!(plan.eval(Loss::Logistic, 0.5), (0.0, 0.0));
    }
}
