//! Property tests for the transport subsystem: AllReduce results are a
//! pure function of (parts, topology plan) — exact for exact inputs,
//! bitwise identical across threaded/serial clusters, and bitwise
//! identical after a round trip through real TCP loopback framing.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};

use fadl::cluster::{CostModel, Cluster};
use fadl::data::partition::{ExamplePartition, Strategy};
use fadl::data::synth;
use fadl::loss::Loss;
use fadl::net::topology;
use fadl::net::wire::{self, read_frame, write_frame, Dec, Enc, Msg};
use fadl::net::{
    Combine, CombineSpec, Command, DualUpdateSpec, LocalSolveSpec, Topology, VecOp,
    VecRef,
};
use fadl::objective::{Shard, ShardCompute, SparseShard};
use fadl::util::proptest::{Pair, Runner, UsizeRange};
use fadl::util::rng::Pcg64;

fn cluster_over(p: usize, threaded: bool) -> Cluster {
    let ds = synth::quick(20.max(4 * p), 8, 4, 77);
    let part = ExamplePartition::build(ds.n(), p, Strategy::Contiguous, 0);
    let workers: Vec<Box<dyn ShardCompute>> = (0..p)
        .map(|i| {
            Box::new(SparseShard::new(Shard::from_dataset(
                &ds,
                &part.assignments[i],
                &part.weights[i],
            ))) as Box<dyn ShardCompute>
        })
        .collect();
    let mut c = Cluster::new(workers, CostModel::default());
    c.threaded = threaded;
    c
}

fn draw_parts(p: usize, m: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::new(seed);
    (0..p)
        .map(|_| (0..m).map(|_| rng.normal() * 10f64.powi(rng.below(5) as i32 - 2)).collect())
        .collect()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Round-trip each part through a real TCP loopback socket (length-
/// prefixed f64-vector frames), then reduce — models the TCP driver's
/// gather without spawning processes.
fn reduce_via_loopback(parts: &[Vec<f64>], plan: &topology::ReducePlan) -> Vec<f64> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().unwrap();
    let sent = parts.to_vec();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        for part in &sent {
            let mut e = Enc::new();
            e.vec_f64(part);
            write_frame(&mut w, &e.buf).expect("frame");
        }
        w.flush().unwrap();
        drop(w);
        // hold the read half open until the client is done
        let _ = read_frame(&mut r);
    });
    let stream = TcpStream::connect(addr).expect("connect");
    let mut r = BufReader::new(stream);
    let mut received = Vec::with_capacity(parts.len());
    for _ in 0..parts.len() {
        let frame = read_frame(&mut r).expect("read").expect("frame");
        let mut d = Dec::new(&frame);
        received.push(d.vec_f64().expect("vec"));
    }
    // close our end so the server's trailing read sees EOF before join
    drop(r);
    server.join().unwrap();
    topology::reduce(received, plan)
}

#[test]
fn reductions_are_exact_for_integer_parts() {
    let gen = Pair(UsizeRange(1, 8), UsizeRange(1, 40));
    Runner::new(48, 0xA11E).run(&gen, |&(p, m)| {
        let mut rng = Pcg64::new((p * 1000 + m) as u64);
        let parts: Vec<Vec<f64>> = (0..p)
            .map(|_| (0..m).map(|_| rng.below(201) as f64 - 100.0).collect())
            .collect();
        let mut want = vec![0.0; m];
        for part in &parts {
            for j in 0..m {
                want[j] += part[j];
            }
        }
        for topo in Topology::all() {
            let got = topology::reduce(parts.clone(), &topo.plan(p, m));
            if got != want {
                return Err(format!("{topo:?} p={p} m={m}: {got:?} != {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn allreduce_bitwise_identical_across_threading_and_topologies() {
    let gen = Pair(UsizeRange(1, 6), UsizeRange(1, 33));
    Runner::new(24, 0xB17).run(&gen, |&(p, m)| {
        let parts = draw_parts(p, m, (31 * p + m) as u64);
        for topo in Topology::all() {
            let reference = topology::reduce(parts.clone(), &topo.plan(p, m));
            for threaded in [false, true] {
                let mut c = cluster_over(p, threaded);
                c.set_topology(topo);
                let got = c.allreduce(parts.clone());
                if bits(&got) != bits(&reference) {
                    return Err(format!(
                        "{topo:?} threaded={threaded} diverged from plan reduce"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn allreduce_bitwise_identical_over_tcp_loopback() {
    let gen = Pair(UsizeRange(1, 6), UsizeRange(1, 25));
    Runner::new(12, 0x7C9).run(&gen, |&(p, m)| {
        let parts = draw_parts(p, m, (47 * p + m) as u64);
        for topo in Topology::all() {
            let plan = topo.plan(p, m);
            let direct = topology::reduce(parts.clone(), &plan);
            let via_wire = reduce_via_loopback(&parts, &plan);
            if bits(&direct) != bits(&via_wire) {
                return Err(format!(
                    "{topo:?} p={p} m={m}: loopback round trip changed bits"
                ));
            }
        }
        Ok(())
    });
}

fn draw_vec(rng: &mut Pcg64, len: usize) -> Vec<f64> {
    (0..len)
        .map(|_| rng.normal() * 10f64.powi(rng.below(7) as i32 - 3))
        .collect()
}

fn draw_spans(rng: &mut Pcg64, n: usize) -> Vec<fadl::metrics::telemetry::Span> {
    // names deliberately include separators, quotes, and non-ASCII to
    // exercise the length-prefixed string encoding
    const NAMES: &[&str] =
        &["cmd:grad", "pool:run", "a\"b\\c", "mesh:allreduce", "Δphase", ""];
    (0..n)
        .map(|_| fadl::metrics::telemetry::Span {
            name: std::borrow::Cow::Borrowed(NAMES[rng.below(NAMES.len())]),
            rank: rng.below(1 << 16) as u32,
            thread: rng.below(1 << 8) as u32,
            t_start_ns: rng.next_u64(),
            t_end_ns: rng.next_u64(),
            bytes: rng.next_u64(),
        })
        .collect()
}

/// Frame a message, push it through the length-prefixed framing, and
/// decode — the exact driver↔worker path minus the socket.
fn wire_roundtrip(msg: &Msg) -> Msg {
    let mut buf = Vec::new();
    wire::send(&mut buf, msg).expect("send");
    let mut cursor = std::io::Cursor::new(buf);
    let back = wire::recv(&mut cursor).expect("recv").expect("frame");
    assert!(wire::recv(&mut cursor).expect("recv").is_none(), "clean EOF");
    back
}

fn draw_vecref(rng: &mut Pcg64, len: usize) -> VecRef {
    if rng.below(3) == 0 {
        VecRef::Reg(rng.below(64) as u32)
    } else {
        VecRef::Inline(draw_vec(rng, len))
    }
}

fn draw_combine(rng: &mut Pcg64) -> CombineSpec {
    let kind = match rng.below(6) {
        0 => Combine::WeightedSum,
        1 => Combine::Direction { anchor: rng.below(32) as u32 },
        2 => Combine::CoverageDirection { anchor: rng.below(32) as u32 },
        3 => Combine::Step { anchor: rng.below(32) as u32, scale: rng.normal() },
        4 => Combine::WeightedAvg,
        _ => Combine::AdmmConsensus { rho: rng.normal().abs(), lambda: rng.normal() },
    };
    CombineSpec {
        weights: draw_vec(rng, rng.below(9)),
        kind,
        store: if rng.below(2) == 0 { Some(rng.below(64) as u32) } else { None },
        dots: (0..rng.below(4))
            .map(|_| (rng.below(32) as u32, rng.below(32) as u32))
            .collect(),
    }
}

#[test]
fn full_vocabulary_frames_roundtrip_bitwise() {
    // every wire-v6 command frame, over random payload sizes *including
    // empty vectors* and both VecRef flavours — the decoded message
    // must equal the encoded one (f64 bits travel raw, so equality here
    // is bitwise)
    let gen = UsizeRange(0, 48);
    Runner::new(40, 0xF00D).run(&gen, |&len| {
        let mut rng = Pcg64::new(len as u64 + 1);
        let msgs = vec![
            Msg::Cmd(Command::Hvp {
                loss: Loss::SquaredHinge,
                s: draw_vecref(&mut rng, len),
            }),
            Msg::Cmd(Command::LossEval {
                loss: Loss::Logistic,
                w: draw_vecref(&mut rng, len),
            }),
            Msg::Cmd(Command::LocalSolve(LocalSolveSpec::AdmmProx {
                loss: Loss::SquaredHinge,
                rho: rng.normal().abs() + 1e-9,
                local_iters: rng.below(20) as u32,
                init: rng.below(2) == 0,
                u_scale: rng.normal(),
                z: draw_vecref(&mut rng, len),
            })),
            Msg::Cmd(Command::LocalSolve(LocalSolveSpec::CocoaSdca {
                lambda: rng.normal().abs() + 1e-12,
                epochs: rng.normal().abs(),
                seed: rng.next_u64(),
                round: rng.next_u64(),
                w: draw_vecref(&mut rng, len),
            })),
            Msg::Cmd(Command::LocalSolve(LocalSolveSpec::SszProx {
                loss: Loss::SquaredHinge,
                lambda: rng.normal(),
                mu: rng.normal(),
                local_iters: rng.below(20) as u32,
                anchor: draw_vecref(&mut rng, len),
                full_grad: draw_vecref(&mut rng, len),
                grad_shift: draw_vecref(&mut rng, len),
            })),
            Msg::Cmd(Command::LocalSolve(LocalSolveSpec::FeatureSolve {
                loss: Loss::SquaredHinge,
                lambda: rng.normal(),
                k_hat: rng.below(30) as u32,
                anchor: draw_vecref(&mut rng, len),
                full_grad: draw_vecref(&mut rng, len),
                subsets: (0..rng.below(5))
                    .map(|_| (0..rng.below(len + 1)).map(|j| j as u32).collect())
                    .collect(),
            })),
            Msg::Cmd(Command::DualUpdate(DualUpdateSpec::AdmmDual)),
            Msg::Cmd(Command::VecOps {
                ops: (0..rng.below(6))
                    .map(|_| match rng.below(5) {
                        0 => VecOp::Copy {
                            dst: rng.below(64) as u32,
                            src: rng.below(64) as u32,
                        },
                        1 => VecOp::Zero { dst: rng.below(64) as u32 },
                        2 => VecOp::Scale { dst: rng.below(64) as u32, a: rng.normal() },
                        3 => VecOp::Axpy {
                            dst: rng.below(64) as u32,
                            a: rng.normal(),
                            src: rng.below(64) as u32,
                        },
                        _ => VecOp::Axpby {
                            dst: rng.below(64) as u32,
                            a: rng.normal(),
                            src: rng.below(64) as u32,
                            b: rng.normal(),
                        },
                    })
                    .collect(),
                dots: (0..rng.below(4))
                    .map(|_| (rng.below(64) as u32, rng.below(64) as u32))
                    .collect(),
            }),
            Msg::Cmd(Command::SetReg {
                reg: rng.below(64) as u32,
                v: draw_vec(&mut rng, len),
            }),
            Msg::Cmd(Command::FetchReg { reg: rng.below(64) as u32 }),
            Msg::Cmd(Command::TestAuprc { w: draw_vecref(&mut rng, len) }),
            Msg::Reply {
                reply: fadl::net::Reply::Vector {
                    v: draw_vec(&mut rng, len),
                    units: rng.normal().abs(),
                },
                secs: rng.normal().abs(),
                queue_ns: rng.next_u64(),
                page_ns: rng.next_u64(),
            },
            Msg::Reply {
                reply: fadl::net::Reply::Scalar { v: rng.normal(), units: 0.0 },
                secs: 0.0,
                queue_ns: 0,
                page_ns: 0,
            },
            Msg::Reply {
                reply: fadl::net::Reply::Dots {
                    vals: draw_vec(&mut rng, rng.below(6)),
                    units: 0.0,
                },
                secs: rng.normal().abs(),
                queue_ns: rng.next_u64(),
                page_ns: rng.next_u64(),
            },
            Msg::Cmd(Command::FetchTelemetry),
            Msg::Reply {
                reply: fadl::net::Reply::Telemetry {
                    spans: draw_spans(&mut rng, rng.below(len + 1)),
                    dropped: rng.next_u64(),
                    units: 0.0,
                },
                secs: 0.0,
                queue_ns: 0,
                page_ns: 0,
            },
            Msg::Mesh {
                addrs: (0..rng.below(9))
                    .map(|r| format!("127.0.0.1:{}", 9000 + r))
                    .collect(),
            },
            Msg::Reduce {
                cmd: Command::Grad {
                    loss: Loss::SquaredHinge,
                    w: draw_vecref(&mut rng, len),
                },
                topology: Topology::all()[rng.below(Topology::all().len())],
                spec: draw_combine(&mut rng),
            },
            Msg::Reduced {
                reply: fadl::net::Reply::Grad {
                    loss: rng.normal(),
                    grad: draw_vec(&mut rng, len),
                    units: rng.normal().abs(),
                },
                data_tx: rng.next_u64(),
                data_rx: rng.next_u64(),
                secs: rng.normal().abs(),
                compute_secs: rng.normal().abs(),
                queue_ns: rng.next_u64(),
                stall_ns: rng.next_u64(),
                overlap_ns: rng.next_u64(),
                page_ns: rng.next_u64(),
                dots: draw_vec(&mut rng, rng.below(5)),
            },
            Msg::Finish {
                sums: (0..rng.below(3))
                    .map(|_| draw_vec(&mut rng, len))
                    .collect(),
            },
            Msg::Finished { dots: draw_vec(&mut rng, rng.below(5)) },
        ];
        for msg in msgs {
            let back = wire_roundtrip(&msg);
            if back != msg {
                return Err(format!("len {len}: {msg:?} != {back:?}"));
            }
        }
        Ok(())
    });
}

/// Random wire batch with adversarial nnz patterns: many empty rows, a
/// rare heavy row, denormal/negative-zero f32 payloads.
fn draw_batch(rng: &mut Pcg64, rows: usize) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
    let mut row_nnz = Vec::with_capacity(rows);
    let mut col_idx = Vec::new();
    let mut values = Vec::new();
    for _ in 0..rows {
        let nnz = match rng.below(5) {
            0 | 1 => 0,                 // empty rows dominate sparse traffic
            2 | 3 => rng.below(4),
            _ => 16 + rng.below(48),    // the occasional heavy row
        };
        row_nnz.push(nnz as u32);
        for _ in 0..nnz {
            col_idx.push(rng.below(1 << 20) as u32);
            values.push(match rng.below(6) {
                0 => 0.0,
                1 => -0.0,
                2 => f32::MIN_POSITIVE / 2.0, // subnormal
                3 => f32::MAX,
                _ => (rng.normal() * 10f64.powi(rng.below(7) as i32 - 3)) as f32,
            });
        }
    }
    (row_nnz, col_idx, values)
}

#[test]
fn serving_frames_roundtrip_bitwise() {
    // the v7 serving vocabulary over random batch shapes, *including the
    // empty batch*: Score's f32 payload and Scores/Publish's f64 payload
    // must travel bit for bit, and ids/epochs at the u64 extremes
    let gen = UsizeRange(0, 48);
    Runner::new(40, 0x5E7E).run(&gen, |&rows| {
        let mut rng = Pcg64::new(rows as u64 + 0xC0FFEE);
        let (row_nnz, col_idx, values) = draw_batch(&mut rng, rows);
        let id = if rng.below(4) == 0 { u64::MAX } else { rng.next_u64() };
        let score = Msg::Score {
            id,
            cols: 1 << 20,
            row_nnz,
            col_idx,
            values: values.clone(),
        };
        let back = wire_roundtrip(&score);
        if back != score {
            return Err(format!("Score rows={rows}: {score:?} != {back:?}"));
        }
        let Msg::Score { values: vback, .. } = back else { unreachable!() };
        for (a, b) in vback.iter().zip(&values) {
            if a.to_bits() != b.to_bits() {
                return Err(format!("Score f32 bits changed: {a} vs {b}"));
            }
        }
        let msgs = vec![
            Msg::Scores {
                id,
                epoch: if rng.below(4) == 0 { u64::MAX } else { rng.next_u64() },
                margins: draw_vec(&mut rng, rows),
            },
            Msg::Publish {
                loss: Loss::Logistic,
                lambda: rng.normal().abs() + 1e-12,
                weights: draw_vec(&mut rng, rng.below(40)),
            },
            Msg::Published { epoch: rng.next_u64() },
        ];
        for msg in msgs {
            let back = wire_roundtrip(&msg);
            if back != msg {
                return Err(format!("rows={rows}: {msg:?} != {back:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn serving_batch_at_width_roundtrips() {
    // a 64k-row Score frame (the protocol's intended max batch) with a
    // mixed nnz profile survives the frame loop intact
    let rows = 1 << 16;
    let mut rng = Pcg64::new(0x64AB);
    let mut row_nnz = Vec::with_capacity(rows);
    let mut col_idx = Vec::new();
    let mut values = Vec::new();
    for i in 0..rows {
        // one pathological row carries 4096 nonzeros; the rest 0–2
        let nnz = if i == rows / 2 { 4096 } else { rng.below(3) };
        row_nnz.push(nnz as u32);
        for _ in 0..nnz {
            col_idx.push(rng.below(1 << 24) as u32);
            values.push(rng.normal() as f32);
        }
    }
    let msg = Msg::Score {
        id: 3,
        cols: 1 << 24,
        row_nnz: row_nnz.clone(),
        col_idx: col_idx.clone(),
        values: values.clone(),
    };
    let Msg::Score {
        row_nnz: rn,
        col_idx: ci,
        values: vs,
        ..
    } = wire_roundtrip(&msg)
    else {
        panic!("wrong variant");
    };
    assert_eq!(rn, row_nnz);
    assert_eq!(ci, col_idx);
    assert_eq!(vs.len(), values.len());
    for (a, b) in vs.iter().zip(&values) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // the 64k-margin reply survives too
    let margins: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    let msg = Msg::Scores { id: 3, epoch: 9, margins: margins.clone() };
    let Msg::Scores { margins: back, .. } = wire_roundtrip(&msg) else {
        panic!("wrong variant");
    };
    for (a, b) in back.iter().zip(&margins) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn full_ring_telemetry_flush_roundtrips() {
    // a worker flushing a completely full span ring (capacity 4096) with
    // overflow recorded in `dropped` survives the frame loop intact
    let mut rng = Pcg64::new(0x7E1E);
    let spans = draw_spans(&mut rng, 4096);
    let msg = Msg::Reply {
        reply: fadl::net::Reply::Telemetry {
            spans: spans.clone(),
            dropped: 517,
            units: 0.0,
        },
        secs: 0.25,
        queue_ns: 12,
        page_ns: 3,
    };
    let Msg::Reply {
        reply: fadl::net::Reply::Telemetry { spans: back, dropped, .. },
        ..
    } = wire_roundtrip(&msg)
    else {
        panic!("wrong variant");
    };
    assert_eq!(back.len(), 4096);
    assert_eq!(dropped, 517);
    assert_eq!(back, spans);
    // the empty flush (telemetry off worker-side) is the common case
    let msg = Msg::Reply {
        reply: fadl::net::Reply::Telemetry {
            spans: Vec::new(),
            dropped: 0,
            units: 0.0,
        },
        secs: 0.0,
        queue_ns: 0,
        page_ns: 0,
    };
    assert_eq!(wire_roundtrip(&msg), msg);
}

#[test]
fn max_length_payload_frames_roundtrip() {
    // a command payload at realistic maximum size (a full m-vector of
    // the paper-scale runs) survives the frame loop bit for bit
    let mut rng = Pcg64::new(0xB16);
    let big = draw_vec(&mut rng, 1 << 16);
    let msg = Msg::Cmd(Command::Hvp {
        loss: Loss::SquaredHinge,
        s: VecRef::Inline(big.clone()),
    });
    let Msg::Cmd(Command::Hvp { s: VecRef::Inline(s), .. }) = wire_roundtrip(&msg)
    else {
        panic!("wrong variant");
    };
    assert_eq!(s.len(), big.len());
    for (a, b) in s.iter().zip(&big) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // a star Finish frame at full width survives too (the sums the
    // driver broadcasts back for the rank-side combine epilogue)
    let msg = Msg::Finish { sums: vec![big.clone(), big.clone()] };
    let Msg::Finish { sums } = wire_roundtrip(&msg) else {
        panic!("wrong variant");
    };
    assert_eq!(sums.len(), 2);
    for s in &sums {
        for (a, b) in s.iter().zip(&big) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    // the subsets list also survives at width (every rank's full J_p)
    let subsets: Vec<Vec<u32>> = (0..64).map(|p| (p..1024).collect()).collect();
    let msg = Msg::Cmd(Command::LocalSolve(LocalSolveSpec::FeatureSolve {
        loss: Loss::SquaredHinge,
        lambda: 1e-6,
        k_hat: 10,
        anchor: VecRef::Inline(vec![]),
        full_grad: VecRef::Reg(0),
        subsets: subsets.clone(),
    }));
    let Msg::Cmd(Command::LocalSolve(LocalSolveSpec::FeatureSolve {
        subsets: back, ..
    })) = wire_roundtrip(&msg)
    else {
        panic!("wrong variant");
    };
    assert_eq!(back, subsets);
}

#[test]
fn weighted_combine_schedules_match_flat_weighted_sum_bitwise() {
    // the combine plane's per-rank weighting (incl. zero-weight ranks)
    // followed by the compiled p2p schedules must land every rank on
    // exactly the bits of the driver-style weighted sum — across
    // m < P, m ∤ P, and P = 1 (a no-op schedule)
    let gen = Pair(UsizeRange(1, 8), UsizeRange(1, 40));
    Runner::new(32, 0x3E1).run(&gen, |&(p, m)| {
        let mut rng = Pcg64::new((61 * p + m) as u64);
        let parts = draw_parts(p, m, (59 * p + m) as u64);
        let weights: Vec<f64> = (0..p)
            .map(|r| if r % 3 == 2 { 0.0 } else { rng.normal().abs() })
            .collect();
        for topo in Topology::all() {
            // driver-style reference: scale each part, then plan-reduce
            let scaled: Vec<Vec<f64>> = parts
                .iter()
                .zip(&weights)
                .map(|(v, &wt)| {
                    let mut v = v.clone();
                    fadl::linalg::scale(wt, &mut v);
                    v
                })
                .collect();
            let plan = topo.plan(p, m);
            let want = topology::reduce(scaled.clone(), &plan);
            for (rank, buf) in
                topology::simulate_schedules(&scaled, &plan).iter().enumerate()
            {
                if bits(buf) != bits(&want) {
                    return Err(format!("{topo:?} p={p} m={m} rank={rank} diverged"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn direction_combine_matches_driver_side_combine_bitwise() {
    // d = Σ w̃_p(v_p − anchor): the worker-side pre-transform + plan sum
    // must equal the old driver-side sub/scale/AllReduce op-for-op
    let gen = Pair(UsizeRange(1, 6), UsizeRange(1, 24));
    Runner::new(24, 0xD1C).run(&gen, |&(p, m)| {
        let mut rng = Pcg64::new((67 * p + m) as u64);
        let parts = draw_parts(p, m, (71 * p + m) as u64);
        let anchor = draw_vec(&mut rng, m);
        let weights: Vec<f64> = (0..p).map(|_| 1.0 / p as f64).collect();
        for topo in Topology::all() {
            // legacy driver combine: d_p = coef·(v_p − w), then reduce
            let legacy: Vec<Vec<f64>> = parts
                .iter()
                .zip(&weights)
                .map(|(v, &coef)| {
                    let mut d = fadl::linalg::sub(v, &anchor);
                    fadl::linalg::scale(coef, &mut d);
                    d
                })
                .collect();
            let plan = topo.plan(p, m);
            let want = topology::reduce(legacy, &plan);
            // combine-plane: per-rank pre_combine with the anchor in a
            // register, then the simulated schedules
            let spec = CombineSpec {
                weights: weights.clone(),
                kind: Combine::Direction { anchor: 0 },
                store: None,
                dots: Vec::new(),
            };
            let mut pre = Vec::with_capacity(p);
            for (rank, v) in parts.iter().enumerate() {
                let mut st = fadl::net::WorkerState::new(rank, p);
                st.set_reg(0, anchor.clone());
                let mut vecs = vec![v.clone()];
                fadl::net::endpoint::pre_combine(&st, &spec, rank, &mut vecs)?;
                pre.push(vecs.pop().unwrap());
            }
            for (rank, buf) in
                topology::simulate_schedules(&pre, &plan).iter().enumerate()
            {
                if bits(buf) != bits(&want) {
                    return Err(format!("{topo:?} p={p} m={m} rank={rank} diverged"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn p2p_schedules_match_plan_reduce_bitwise() {
    // the compiled per-rank send/recv/accumulate schedules, executed
    // over simulated FIFO connections, must land every rank on exactly
    // the bits the flat plan execution produces — for every topology,
    // including m < P (empty ring chunks) and m not divisible by P
    let gen = Pair(UsizeRange(1, 8), UsizeRange(1, 40));
    Runner::new(32, 0x9E9).run(&gen, |&(p, m)| {
        let parts = draw_parts(p, m, (53 * p + m) as u64);
        for topo in Topology::all() {
            let plan = topo.plan(p, m);
            let want = topology::reduce(parts.clone(), &plan);
            let bufs = topology::simulate_schedules(&parts, &plan);
            for (rank, buf) in bufs.iter().enumerate() {
                if bits(buf) != bits(&want) {
                    return Err(format!(
                        "{topo:?} p={p} m={m}: rank {rank} diverged from the plan"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn plan_byte_accounting_matches_simulated_wire_exactly() {
    // the static accounting the cost model, benches, and parity gates
    // rely on — RankSchedule::send_bytes per rank and their sum
    // ReducePlan::mesh_bytes — must equal the bytes the FIFO executor
    // actually enqueues, for every plan family over adversarial shapes
    // (P non-power-of-two, P = 1, m < P, m ∤ P, single-element chunks)
    let gen = Pair(UsizeRange(1, 9), UsizeRange(1, 45));
    Runner::new(40, 0xB77E).run(&gen, |&(p, m)| {
        let parts = draw_parts(p, m, (83 * p + m) as u64);
        for topo in Topology::all() {
            let plan = topo.plan(p, m);
            let (_, sent) = topology::simulate_schedules_counting(&parts, &plan);
            let mut total = 0u64;
            for (rank, &wire) in sent.iter().enumerate() {
                let claimed = plan.rank_schedule(rank).send_bytes();
                if claimed != wire {
                    return Err(format!(
                        "{topo:?} p={p} m={m} rank {rank}: \
                         send_bytes claims {claimed}, wire moved {wire}"
                    ));
                }
                total += wire;
            }
            if plan.mesh_bytes() != total {
                return Err(format!(
                    "{topo:?} p={p} m={m}: mesh_bytes {} != simulated {total}",
                    plan.mesh_bytes()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn p2p_schedule_edge_cases() {
    // m < P: ring chunks with lo == hi must vanish from the schedules
    for (p, m) in [(6usize, 3usize), (4, 1), (5, 7), (7, 20)] {
        for topo in Topology::all() {
            let parts = draw_parts(p, m, (7 * p + m) as u64);
            let plan = topo.plan(p, m);
            let want = topology::reduce(parts.clone(), &plan);
            for buf in topology::simulate_schedules(&parts, &plan) {
                assert_eq!(bits(&buf), bits(&want), "{topo:?} p={p} m={m}");
            }
        }
    }
    // P = 1: the schedule must degenerate to a no-op
    for topo in Topology::all() {
        let scheds = topo.plan(1, 9).rank_schedules();
        assert_eq!(scheds.len(), 1);
        assert!(scheds[0].ops.is_empty(), "{topo:?}: {:?}", scheds[0].ops);
        let parts = vec![vec![1.25, -3.5, 0.0]];
        assert_eq!(
            topology::simulate_schedules(&parts, &topo.plan(1, 3))[0],
            parts[0]
        );
    }
}

#[test]
fn topologies_agree_within_rounding() {
    // different summation orders may differ in the last bits, but the
    // sums must agree to fp-rounding accuracy
    let p = 6;
    let m = 20;
    let parts = draw_parts(p, m, 99);
    let tree = topology::reduce(parts.clone(), &Topology::Tree.plan(p, m));
    for topo in [
        Topology::Flat,
        Topology::Ring,
        Topology::HalvingDoubling,
        Topology::PipelinedTree,
    ] {
        let other = topology::reduce(parts.clone(), &topo.plan(p, m));
        for j in 0..m {
            let scale = tree[j].abs().max(1.0);
            assert!(
                (tree[j] - other[j]).abs() <= 1e-12 * scale,
                "{topo:?} j={j}: {} vs {}",
                tree[j],
                other[j]
            );
        }
    }
}
