//! Property tests for the transport subsystem: AllReduce results are a
//! pure function of (parts, topology plan) — exact for exact inputs,
//! bitwise identical across threaded/serial clusters, and bitwise
//! identical after a round trip through real TCP loopback framing.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};

use fadl::cluster::{CostModel, Cluster};
use fadl::data::partition::{ExamplePartition, Strategy};
use fadl::data::synth;
use fadl::net::topology;
use fadl::net::wire::{read_frame, write_frame, Dec, Enc};
use fadl::net::Topology;
use fadl::objective::{Shard, ShardCompute, SparseShard};
use fadl::util::proptest::{Pair, Runner, UsizeRange};
use fadl::util::rng::Pcg64;

fn cluster_over(p: usize, threaded: bool) -> Cluster {
    let ds = synth::quick(20.max(4 * p), 8, 4, 77);
    let part = ExamplePartition::build(ds.n(), p, Strategy::Contiguous, 0);
    let workers: Vec<Box<dyn ShardCompute>> = (0..p)
        .map(|i| {
            Box::new(SparseShard::new(Shard::from_dataset(
                &ds,
                &part.assignments[i],
                &part.weights[i],
            ))) as Box<dyn ShardCompute>
        })
        .collect();
    let mut c = Cluster::new(workers, CostModel::default());
    c.threaded = threaded;
    c
}

fn draw_parts(p: usize, m: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::new(seed);
    (0..p)
        .map(|_| (0..m).map(|_| rng.normal() * 10f64.powi(rng.below(5) as i32 - 2)).collect())
        .collect()
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Round-trip each part through a real TCP loopback socket (length-
/// prefixed f64-vector frames), then reduce — models the TCP driver's
/// gather without spawning processes.
fn reduce_via_loopback(parts: &[Vec<f64>], plan: &topology::ReducePlan) -> Vec<f64> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
    let addr = listener.local_addr().unwrap();
    let sent = parts.to_vec();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut w = BufWriter::new(stream);
        for part in &sent {
            let mut e = Enc::new();
            e.vec_f64(part);
            write_frame(&mut w, &e.buf).expect("frame");
        }
        w.flush().unwrap();
        drop(w);
        // hold the read half open until the client is done
        let _ = read_frame(&mut r);
    });
    let stream = TcpStream::connect(addr).expect("connect");
    let mut r = BufReader::new(stream);
    let mut received = Vec::with_capacity(parts.len());
    for _ in 0..parts.len() {
        let frame = read_frame(&mut r).expect("read").expect("frame");
        let mut d = Dec::new(&frame);
        received.push(d.vec_f64().expect("vec"));
    }
    // close our end so the server's trailing read sees EOF before join
    drop(r);
    server.join().unwrap();
    topology::reduce(received, plan)
}

#[test]
fn reductions_are_exact_for_integer_parts() {
    let gen = Pair(UsizeRange(1, 8), UsizeRange(1, 40));
    Runner::new(48, 0xA11E).run(&gen, |&(p, m)| {
        let mut rng = Pcg64::new((p * 1000 + m) as u64);
        let parts: Vec<Vec<f64>> = (0..p)
            .map(|_| (0..m).map(|_| rng.below(201) as f64 - 100.0).collect())
            .collect();
        let mut want = vec![0.0; m];
        for part in &parts {
            for j in 0..m {
                want[j] += part[j];
            }
        }
        for topo in Topology::all() {
            let got = topology::reduce(parts.clone(), &topo.plan(p, m));
            if got != want {
                return Err(format!("{topo:?} p={p} m={m}: {got:?} != {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn allreduce_bitwise_identical_across_threading_and_topologies() {
    let gen = Pair(UsizeRange(1, 6), UsizeRange(1, 33));
    Runner::new(24, 0xB17).run(&gen, |&(p, m)| {
        let parts = draw_parts(p, m, (31 * p + m) as u64);
        for topo in Topology::all() {
            let reference = topology::reduce(parts.clone(), &topo.plan(p, m));
            for threaded in [false, true] {
                let mut c = cluster_over(p, threaded);
                c.set_topology(topo);
                let got = c.allreduce(parts.clone());
                if bits(&got) != bits(&reference) {
                    return Err(format!(
                        "{topo:?} threaded={threaded} diverged from plan reduce"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn allreduce_bitwise_identical_over_tcp_loopback() {
    let gen = Pair(UsizeRange(1, 6), UsizeRange(1, 25));
    Runner::new(12, 0x7C9).run(&gen, |&(p, m)| {
        let parts = draw_parts(p, m, (47 * p + m) as u64);
        for topo in Topology::all() {
            let plan = topo.plan(p, m);
            let direct = topology::reduce(parts.clone(), &plan);
            let via_wire = reduce_via_loopback(&parts, &plan);
            if bits(&direct) != bits(&via_wire) {
                return Err(format!(
                    "{topo:?} p={p} m={m}: loopback round trip changed bits"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn topologies_agree_within_rounding() {
    // different summation orders may differ in the last bits, but the
    // sums must agree to fp-rounding accuracy
    let p = 6;
    let m = 20;
    let parts = draw_parts(p, m, 99);
    let tree = topology::reduce(parts.clone(), &Topology::Tree.plan(p, m));
    for topo in [Topology::Flat, Topology::Ring] {
        let other = topology::reduce(parts.clone(), &topo.plan(p, m));
        for j in 0..m {
            let scale = tree[j].abs().max(1.0);
            assert!(
                (tree[j] - other[j]).abs() <= 1e-12 * scale,
                "{topo:?} j={j}: {} vs {}",
                tree[j],
                other[j]
            );
        }
    }
}
