//! Integration tests for the serving plane: a real TCP server, the
//! blocking client, and the train → artifact → serve joint.
//!
//! The load-bearing claims: margins scored over the wire are bitwise
//! equal to the in-process reference; a hot swap mid-connection
//! attributes every reply to exactly one published epoch; the online
//! updater's flush lands over the same `Publish` path a retrain uses.

use std::sync::Arc;

use fadl::coordinator::artifact::{ModelArtifact, Provenance};
use fadl::coordinator::config::Config;
use fadl::coordinator::driver;
use fadl::data::synth;
use fadl::linalg::Csr;
use fadl::loss::Loss;
use fadl::objective::{Shard, ShardCompute, SparseShard};
use fadl::serve::online::OnlineUpdater;
use fadl::serve::{client::ScoreClient, server, Front};
use fadl::util::rng::Pcg64;

fn artifact(m: usize, seed: u64) -> ModelArtifact {
    let mut rng = Pcg64::new(seed);
    ModelArtifact {
        loss: Loss::SquaredHinge,
        lambda: 1e-4,
        m,
        weights: (0..m).map(|_| rng.normal()).collect(),
        provenance: Provenance {
            method: "fadl".into(),
            dataset: "quick".into(),
            nodes: 2,
            seed,
            outer_iters: 5,
            final_f: 0.5,
        },
    }
}

fn inproc_margins(x: &Csr, w: &[f64]) -> Vec<f64> {
    let rows = x.rows;
    SparseShard::new(Shard { x: x.clone(), y: vec![0.0; rows], c: vec![1.0; rows] })
        .margins(w)
}

fn assert_bits(got: &[f64], want: &[f64]) {
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(want) {
        assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
    }
}

#[test]
fn served_margins_bitwise_equal_inproc_over_tcp() {
    let a = artifact(40, 11);
    let ds = synth::quick(200, 40, 8, 12);
    let front = Arc::new(Front::from_artifact(&a, 2, 2));
    let (addr, _h) = server::spawn(front, "127.0.0.1:0").unwrap();
    let mut client = ScoreClient::connect(&addr.to_string()).unwrap();
    // several batch shapes, including the empty batch and batches with
    // all-empty rows
    for (start, count) in [(0usize, 64usize), (64, 1), (65, 0), (70, 128)] {
        let rows: Vec<Vec<(u32, f32)>> = (0..count)
            .map(|i| ds.x.row((start + i) % ds.n()).collect())
            .collect();
        let x = Csr::from_rows(ds.m(), &rows);
        let want = inproc_margins(&x, &a.weights);
        let (epoch, got) = client.score_csr(&x).unwrap();
        assert_eq!(epoch, 1);
        assert_bits(&got, &want);
        // the row-list entry point must hit the same path
        let (epoch, got) = client.score_rows(ds.m(), &rows).unwrap();
        assert_eq!(epoch, 1);
        assert_bits(&got, &want);
    }
    client.shutdown();
}

#[test]
fn hot_swap_attributes_every_reply_to_one_epoch() {
    let a = artifact(16, 21);
    let w2: Vec<f64> = a.weights.iter().map(|w| w + 1.0).collect();
    let x = Csr::from_rows(16, &[vec![(0, 1.0), (5, -2.0)], vec![(15, 0.5)]]);
    let ref1 = inproc_margins(&x, &a.weights);
    let ref2 = inproc_margins(&x, &w2);
    let front = Arc::new(Front::from_artifact(&a, 1, 1));
    let (addr, _h) = server::spawn(front, "127.0.0.1:0").unwrap();
    let mut scorer = ScoreClient::connect(&addr.to_string()).unwrap();
    let mut publisher = ScoreClient::connect(&addr.to_string()).unwrap();
    // before the swap: epoch 1, epoch-1 bits
    let (e, m) = scorer.score_csr(&x).unwrap();
    assert_eq!(e, 1);
    assert_bits(&m, &ref1);
    // the swap lands on a *different* connection — the front is shared
    let e2 = publisher.publish(a.loss, a.lambda, w2).unwrap();
    assert_eq!(e2, 2);
    // after the swap: the same scoring connection sees epoch 2 and the
    // new weights' bits — never a mix
    let (e, m) = scorer.score_csr(&x).unwrap();
    assert_eq!(e, 2);
    assert_bits(&m, &ref2);
    // a dimension-mismatched publish is refused server-side and the
    // epoch does not advance
    assert!(publisher.publish(a.loss, a.lambda, vec![1.0]).is_err());
    let mut fresh = ScoreClient::connect(&addr.to_string()).unwrap();
    let (e, _) = fresh.score_csr(&x).unwrap();
    assert_eq!(e, 2);
    scorer.shutdown();
    fresh.shutdown();
}

#[test]
fn online_updater_flush_publishes_over_the_wire_path() {
    // the updater flushes into the same Front a TCP server scores from:
    // a client connected across the swap observes the new epoch
    let a = artifact(30, 31);
    let ds = synth::quick(300, 30, 6, 32);
    let front = Arc::new(Front::from_artifact(&a, 1, 2));
    let (addr, _h) = server::spawn(front.clone(), "127.0.0.1:0").unwrap();
    let mut client = ScoreClient::connect(&addr.to_string()).unwrap();
    let x = Csr::from_rows(
        30,
        &(0..16)
            .map(|i| ds.x.row(i).collect::<Vec<_>>())
            .collect::<Vec<_>>(),
    );
    let (e, _) = client.score_csr(&x).unwrap();
    assert_eq!(e, 1);
    let mut upd = OnlineUpdater::new(3, 0.5, 7);
    for i in 0..ds.n() {
        upd.absorb(ds.x.row(i).collect(), ds.y[i]);
    }
    let e2 = upd.flush(&front).unwrap().expect("non-empty flush publishes");
    assert_eq!(e2, 2);
    // the served margins now carry the flushed weights' bits
    let want = inproc_margins(&x, &front.model().weights);
    let (e, m) = client.score_csr(&x).unwrap();
    assert_eq!(e, 2);
    assert_bits(&m, &want);
    client.shutdown();
}

#[test]
fn train_artifact_serve_joint_end_to_end() {
    // the full joint: train through the driver with model_out, load the
    // artifact, serve it, and demand the served margins match scoring
    // the training weights in-process — bit for bit
    let dir = std::env::temp_dir().join(format!("fadl_serve_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("model.fadl").to_string_lossy().to_string();
    let cfg = Config {
        name: "serve-it".into(),
        dataset: "quick".into(),
        quick_n: 240,
        quick_m: 32,
        quick_nnz: 6,
        nodes: 2,
        max_outer: 4,
        model_out: Some(model_path.clone()),
        ..Config::default()
    };
    let exp = driver::prepare(&cfg).unwrap();
    let (w, _) = driver::run(&exp).unwrap();
    let a = ModelArtifact::load(&model_path).unwrap();
    assert_bits(&a.weights, &w);
    let front = Arc::new(Front::from_artifact(&a, 2, 2));
    let (addr, _h) = server::spawn(front, "127.0.0.1:0").unwrap();
    let mut client = ScoreClient::connect(&addr.to_string()).unwrap();
    let rows: Vec<Vec<(u32, f32)>> =
        (0..50).map(|i| exp.train.x.row(i).collect()).collect();
    let x = Csr::from_rows(exp.train.m(), &rows);
    let (epoch, got) = client.score_csr(&x).unwrap();
    assert_eq!(epoch, 1);
    assert_bits(&got, &inproc_margins(&x, &w));
    client.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_batch_aborts_cleanly_without_killing_the_server() {
    let a = artifact(8, 41);
    let front = Arc::new(Front::from_artifact(&a, 1, 1));
    let (addr, _h) = server::spawn(front, "127.0.0.1:0").unwrap();
    // a batch whose m disagrees with the served model: the server must
    // reply Abort (surfaced as Err) and stay up for new connections
    let mut bad = ScoreClient::connect(&addr.to_string()).unwrap();
    let x = Csr::from_rows(9, &[vec![(8, 1.0)]]);
    assert!(bad.score_csr(&x).is_err());
    let mut ok = ScoreClient::connect(&addr.to_string()).unwrap();
    let good = Csr::from_rows(8, &[vec![(7, 1.0)]]);
    let (epoch, m) = ok.score_csr(&good).unwrap();
    assert_eq!(epoch, 1);
    assert_eq!(m.len(), 1);
    ok.shutdown();
}
